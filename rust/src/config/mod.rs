//! Typed configuration for the engine and experiments.
//!
//! Configs load from JSON files (`--config path.json`) and/or CLI
//! overrides; presets encode the paper's L-W-CR budget grids.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::compress::{AllocatorKind, PolicyKind};
use crate::kvcache::KvDtype;
use crate::util::{Args, Json};

/// Engine-level configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Artifact directory (manifest.json, hlo/, weights_*.bin).
    pub artifacts: PathBuf,
    /// Model variant tag from the manifest (base, dms_w16_cr4, …).
    pub variant: String,
    /// Executor lane count (must match an exported decode batch size).
    pub batch: usize,
    /// Slot capacity per (layer, KV-head) (must match an exported S).
    pub slots: usize,
    /// Compression policy applied at decode time.
    pub policy: PolicyKind,
    /// Nominal compression ratio (budget divisor for TOVA/H2O/Quest;
    /// informational for DMS, whose CR is learned).
    pub cr: f64,
    /// Sampling temperature (0 = greedy).
    pub temperature: f64,
    /// Top-k truncation for sampling (0 = disabled).
    pub top_k: usize,
    /// Use the pure-jnp (fused) decode executable instead of Pallas.
    pub use_jnp_decode: bool,
    /// Buffered execution: device-resident parameter buffers +
    /// slice→device input uploads (§Perf optimization). `--literal-exec`
    /// falls back to per-step literal uploads for comparison.
    pub buffered_exec: bool,
    /// Parallelize per-lane host work (policy scoring, sampling) across
    /// scoped threads, one per active lane. `--serial-lanes` disables
    /// it for debugging/comparison; results are identical either way.
    pub lane_threads: bool,
    /// Retain clean prompt pages of completed requests in the radix
    /// prefix index and admit repeated prompts at the divergence point.
    /// `--no-prefix-cache` disables it for comparison.
    pub prefix_cache: bool,
    /// Retained-page budget of the prefix index; least-recently-used
    /// prefixes are released beyond it (`--prefix-pages`).
    pub prefix_cache_pages: usize,
    /// Storage format of pool-owned KV page payloads (`--kv-dtype
    /// f32|q8|q4`). Quantized formats shrink host bytes-per-cached-
    /// token of the COW pool and prefix cache ~3–5× at a bounded,
    /// documented precision cost (docs/NUMERICS.md); lane views and
    /// executor uploads stay f32 either way.
    pub kv_dtype: KvDtype,
    /// Budget allocator shaping each chain's per-(layer, KV-head)
    /// budget plan (`--allocator uniform|pyramid|adaptive`). `uniform`
    /// reproduces the scalar App. F.1 budget bit-exactly; `pyramid`
    /// front-loads shallow layers; `adaptive` re-plans from per-head
    /// attention statistics during decode (see docs/POLICIES.md).
    pub allocator: AllocatorKind,
    /// RAM budget in bytes for the cold tier of the prefix cache
    /// (`--cold-tier-bytes`). Pages LRU-trimmed from the hot prefix
    /// index are demoted into this budget as compressed blocks instead
    /// of freed; a later hit promotes them back at the cost of one
    /// dequant-on-upload rather than a full re-prefill. 0 (the
    /// default) disables the tier (see docs/ARCHITECTURE.md).
    pub cold_tier_bytes: usize,
    /// Storage dtype demoted cold blocks are re-encoded into
    /// (`--cold-dtype f32|q8|q4`). This is the *second lossy boundary*
    /// of docs/NUMERICS.md: demotion may requantize once; promotion
    /// never re-encodes.
    pub cold_dtype: KvDtype,
    /// Directory for spilling cold blocks past the RAM budget
    /// (`--spill-dir`). When unset, over-budget cold blocks are
    /// evicted instead of spilled.
    pub spill_dir: Option<PathBuf>,
    /// Decode steps between adaptive re-plans of a chain's budget plan
    /// (`--replan-interval`; ignored by the signal-free allocators).
    pub replan_interval: usize,
    /// Flight-recorder capacity in events (`--trace-events N`). 0 (the
    /// default) installs the no-op sink: tracing is disabled and the
    /// emit path is a single branch (see docs/OBSERVABILITY.md).
    pub trace_events: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            variant: "base".into(),
            batch: 8,
            slots: 320,
            policy: PolicyKind::Vanilla,
            cr: 1.0,
            temperature: 0.7,
            top_k: 0,
            use_jnp_decode: false,
            buffered_exec: true,
            lane_threads: true,
            prefix_cache: true,
            prefix_cache_pages: 1024,
            kv_dtype: KvDtype::F32,
            allocator: AllocatorKind::Uniform,
            cold_tier_bytes: 0,
            cold_dtype: KvDtype::Q4,
            spill_dir: None,
            replan_interval: 32,
            trace_events: 0,
        }
    }
}

impl EngineConfig {
    /// Apply CLI overrides (`--artifacts`, `--variant`, `--policy`,
    /// `--cr`, `--temp`, `--batch`, `--slots`, `--jnp-decode`).
    pub fn with_args(mut self, args: &Args) -> Result<Self> {
        if let Some(v) = args.get("artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = args.get("variant") {
            self.variant = v.to_string();
        }
        if let Some(v) = args.get("policy") {
            self.policy = v.parse()?;
        }
        self.cr = args.get_f64("cr", self.cr)?;
        self.temperature = args.get_f64("temp", self.temperature)?;
        self.batch = args.get_usize("batch", self.batch)?;
        self.slots = args.get_usize("slots", self.slots)?;
        self.top_k = args.get_usize("top-k", self.top_k)?;
        if args.flag("jnp-decode") {
            self.use_jnp_decode = true;
        }
        if args.flag("literal-exec") {
            self.buffered_exec = false;
        }
        if args.flag("serial-lanes") {
            self.lane_threads = false;
        }
        if args.flag("no-prefix-cache") {
            self.prefix_cache = false;
        }
        self.prefix_cache_pages = args.get_usize("prefix-pages", self.prefix_cache_pages)?;
        if let Some(v) = args.get("kv-dtype") {
            self.kv_dtype = v.parse()?;
        }
        if let Some(v) = args.get("allocator") {
            self.allocator = v.parse()?;
        }
        self.cold_tier_bytes = args.get_usize("cold-tier-bytes", self.cold_tier_bytes)?;
        if let Some(v) = args.get("cold-dtype") {
            self.cold_dtype = v.parse()?;
        }
        if let Some(v) = args.get("spill-dir") {
            self.spill_dir = Some(PathBuf::from(v));
        }
        self.replan_interval =
            args.get_usize("replan-interval", self.replan_interval)?.max(1);
        self.trace_events = args.get_usize("trace-events", self.trace_events)?;
        if args.flag("trace") && self.trace_events == 0 {
            self.trace_events = crate::trace::DEFAULT_CAPACITY;
        }
        Ok(self)
    }

    /// Configuration every paper experiment driver starts from: the
    /// paper's metrics exclude cross-request prefix caching, its
    /// figures assume exact (f32) cache payloads, and its budgets are
    /// the uniform App. F.1 scalar rule — all three are pinned here
    /// **by construction** instead of per-driver, so experiment
    /// outputs stay byte-identical no matter how the serving defaults
    /// evolve.
    pub fn paper_fidelity(artifacts: &Path) -> Self {
        Self {
            artifacts: artifacts.to_path_buf(),
            prefix_cache: false,
            kv_dtype: KvDtype::F32,
            allocator: AllocatorKind::Uniform,
            ..Self::default()
        }
    }

    /// Load overrides from a JSON config file, then CLI on top.
    pub fn from_file(path: &Path) -> Result<Self> {
        let j = Json::parse_file(path)?;
        let mut cfg = Self::default();
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            cfg.artifacts = PathBuf::from(v);
        }
        if let Some(v) = j.get("variant").and_then(Json::as_str) {
            cfg.variant = v.to_string();
        }
        if let Some(v) = j.get("policy").and_then(Json::as_str) {
            cfg.policy = v.parse()?;
        }
        if let Some(v) = j.get("cr").and_then(|x| x.as_f64()) {
            cfg.cr = v;
        }
        if let Some(v) = j.get("temperature").and_then(|x| x.as_f64()) {
            cfg.temperature = v;
        }
        if let Some(v) = j.get("batch").and_then(|x| x.as_usize()) {
            cfg.batch = v;
        }
        if let Some(v) = j.get("slots").and_then(|x| x.as_usize()) {
            cfg.slots = v;
        }
        if let Some(v) = j.get("lane_threads").and_then(Json::as_bool) {
            cfg.lane_threads = v;
        }
        if let Some(v) = j.get("prefix_cache").and_then(Json::as_bool) {
            cfg.prefix_cache = v;
        }
        if let Some(v) = j.get("prefix_cache_pages").and_then(|x| x.as_usize()) {
            cfg.prefix_cache_pages = v;
        }
        if let Some(v) = j.get("kv_dtype").and_then(Json::as_str) {
            cfg.kv_dtype = v.parse()?;
        }
        if let Some(v) = j.get("allocator").and_then(Json::as_str) {
            cfg.allocator = v.parse()?;
        }
        if let Some(v) = j.get("cold_tier_bytes").and_then(|x| x.as_usize()) {
            cfg.cold_tier_bytes = v;
        }
        if let Some(v) = j.get("cold_dtype").and_then(Json::as_str) {
            cfg.cold_dtype = v.parse()?;
        }
        if let Some(v) = j.get("spill_dir").and_then(Json::as_str) {
            cfg.spill_dir = Some(PathBuf::from(v));
        }
        if let Some(v) = j.get("replan_interval").and_then(|x| x.as_usize()) {
            cfg.replan_interval = v.max(1);
        }
        if let Some(v) = j.get("trace_events").and_then(|x| x.as_usize()) {
            cfg.trace_events = v;
        }
        Ok(cfg)
    }
}

/// How the cluster router picks a replica for an incoming request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Score replicas by shadow-prefix-index hit length first (send a
    /// repeated prompt to the replica whose cache already holds its
    /// prefix), tie-broken by load. The default: on repeated-prefix
    /// traffic it converts routing into prefix-cache hit rate.
    #[default]
    Prefix,
    /// Pure load balancing: least (in-flight chains + queued chains),
    /// ties to the lowest replica id.
    LeastLoaded,
    /// Cycle replica ids in arrival order, ignoring state entirely
    /// (the affinity-blind baseline the bench compares against).
    RoundRobin,
}

impl RoutingPolicy {
    /// CLI/config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::Prefix => "prefix",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::RoundRobin => "round-robin",
        }
    }
}

impl std::str::FromStr for RoutingPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "prefix" | "prefix-affinity" => RoutingPolicy::Prefix,
            "least-loaded" | "least_loaded" => RoutingPolicy::LeastLoaded,
            "round-robin" | "round_robin" | "rr" => RoutingPolicy::RoundRobin,
            other => bail!(
                "unknown routing policy '{other}' \
                 (expected prefix, least-loaded, or round-robin)"
            ),
        })
    }
}

/// Serving-cluster shape: how many engine replicas sit behind the
/// router and how requests are assigned to them.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Independent engine replicas, each with its own cache store,
    /// page pool, and prefix index (`--replicas N`).
    pub replicas: usize,
    /// Admission scoring (`--routing prefix|least-loaded|round-robin`).
    pub routing: RoutingPolicy,
    /// Migrate queued (never installed) requests from hot replicas to
    /// idle ones (`--no-steal` disables the fallback).
    pub steal: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            replicas: 1,
            routing: RoutingPolicy::Prefix,
            steal: true,
        }
    }
}

impl ClusterConfig {
    /// Apply CLI overrides (`--replicas`, `--routing`, `--no-steal`).
    pub fn with_args(mut self, args: &Args) -> Result<Self> {
        self.replicas = args.get_usize("replicas", self.replicas)?.max(1);
        if let Some(v) = args.get("routing") {
            self.routing = v.parse()?;
        }
        if args.flag("no-steal") {
            self.steal = false;
        }
        Ok(self)
    }
}

/// One L-W-CR budget point (paper §5.1: sequence-length cap ×
/// parallel width × compression ratio).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BudgetPoint {
    /// Max total tokens per chain (prompt + generation).
    pub max_len: usize,
    /// Number of parallel reasoning chains.
    pub width: usize,
    /// Compression ratio (1 for vanilla).
    pub cr: f64,
}

impl BudgetPoint {
    pub fn label(&self) -> String {
        format!("{}-{}-{}", self.max_len, self.width, self.cr)
    }
}

/// The budget grid used by the Pareto experiments. Scaled-down version
/// of the paper's {8K..32K} × {1..8} × {1,4,8} grid (our contexts are
/// ~1/100 of Qwen-R1's; see DESIGN.md §2).
pub fn budget_grid(policy: PolicyKind) -> Vec<BudgetPoint> {
    let lens = [96usize, 160, 256];
    let widths = [1usize, 2, 4, 8];
    let crs: &[f64] = match policy {
        PolicyKind::Vanilla => &[1.0],
        PolicyKind::Dms => &[4.0, 8.0],
        _ => &[4.0, 8.0],
    };
    let mut grid = Vec::new();
    for &l in &lens {
        for &w in &widths {
            for &cr in crs {
                grid.push(BudgetPoint {
                    max_len: l,
                    width: w,
                    cr,
                });
            }
        }
    }
    grid
}

/// Parse a comma-separated task list.
pub fn parse_tasks(arg: Option<&str>, default: &[&str]) -> Result<Vec<String>> {
    let names: Vec<String> = match arg {
        None => default.iter().map(|s| s.to_string()).collect(),
        Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
    };
    for n in &names {
        if !crate::tasks::suite_names().contains(&n.as_str()) {
            bail!("unknown task suite '{n}'");
        }
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_and_overrides() {
        let args = Args::parse(
            "--variant dms_w16_cr4 --policy dms --cr 4 --temp 0.9"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = EngineConfig::default().with_args(&args).unwrap();
        assert_eq!(cfg.variant, "dms_w16_cr4");
        assert_eq!(cfg.policy, PolicyKind::Dms);
        assert_eq!(cfg.cr, 4.0);
        assert_eq!(cfg.temperature, 0.9);
        assert_eq!(cfg.kv_dtype, KvDtype::F32, "exact payloads by default");
    }

    #[test]
    fn kv_dtype_override_and_validation() {
        let args = Args::parse("--kv-dtype q8".split_whitespace().map(String::from));
        let cfg = EngineConfig::default().with_args(&args).unwrap();
        assert_eq!(cfg.kv_dtype, KvDtype::Q8);
        let args = Args::parse("--kv-dtype bf16".split_whitespace().map(String::from));
        assert!(EngineConfig::default().with_args(&args).is_err());
    }

    #[test]
    fn paper_fidelity_pins_cache_free_exact_payloads() {
        let cfg = EngineConfig::paper_fidelity(Path::new("arts"));
        assert!(!cfg.prefix_cache, "paper metrics exclude the prefix cache");
        assert_eq!(cfg.kv_dtype, KvDtype::F32, "paper figures assume exact K/V");
        assert_eq!(
            cfg.allocator,
            AllocatorKind::Uniform,
            "paper budgets are the uniform App. F.1 rule"
        );
        assert_eq!(cfg.artifacts, PathBuf::from("arts"));
        // everything else follows the serving defaults
        assert_eq!(cfg.batch, EngineConfig::default().batch);
    }

    #[test]
    fn allocator_override_and_validation() {
        let args = Args::parse(
            "--allocator pyramid --replan-interval 8"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = EngineConfig::default().with_args(&args).unwrap();
        assert_eq!(cfg.allocator, AllocatorKind::Pyramid);
        assert_eq!(cfg.replan_interval, 8);
        let args = Args::parse("--allocator zigzag".split_whitespace().map(String::from));
        assert!(EngineConfig::default().with_args(&args).is_err());
        // defaults: uniform allocation, 32-step re-plan cadence
        assert_eq!(EngineConfig::default().allocator, AllocatorKind::Uniform);
        assert_eq!(EngineConfig::default().replan_interval, 32);
        // replan interval is clamped to at least one step
        let args = Args::parse("--replan-interval 0".split_whitespace().map(String::from));
        assert_eq!(
            EngineConfig::default().with_args(&args).unwrap().replan_interval,
            1
        );
    }

    #[test]
    fn cold_tier_overrides_and_validation() {
        // defaults: tier disabled, q4 cold payloads, no spill
        let cfg = EngineConfig::default();
        assert_eq!(cfg.cold_tier_bytes, 0, "cold tier off by default");
        assert_eq!(cfg.cold_dtype, KvDtype::Q4);
        assert_eq!(cfg.spill_dir, None);
        let args = Args::parse(
            "--cold-tier-bytes 1048576 --cold-dtype q8 --spill-dir /tmp/spill"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = EngineConfig::default().with_args(&args).unwrap();
        assert_eq!(cfg.cold_tier_bytes, 1 << 20);
        assert_eq!(cfg.cold_dtype, KvDtype::Q8);
        assert_eq!(cfg.spill_dir, Some(PathBuf::from("/tmp/spill")));
        // cold dtype goes through the same validated KvDtype parser
        let args = Args::parse("--cold-dtype bf16".split_whitespace().map(String::from));
        assert!(EngineConfig::default().with_args(&args).is_err());
    }

    #[test]
    fn trace_flag_and_capacity_override() {
        assert_eq!(EngineConfig::default().trace_events, 0, "tracing off by default");
        let args = Args::parse(["--trace".to_string()].into_iter());
        let cfg = EngineConfig::default().with_args(&args).unwrap();
        assert_eq!(cfg.trace_events, crate::trace::DEFAULT_CAPACITY);
        let args = Args::parse("--trace-events 128".split_whitespace().map(String::from));
        let cfg = EngineConfig::default().with_args(&args).unwrap();
        assert_eq!(cfg.trace_events, 128);
    }

    #[test]
    fn cluster_config_overrides_and_validation() {
        let args = Args::parse(
            "--replicas 4 --routing round-robin --no-steal"
                .split_whitespace()
                .map(String::from),
        );
        let c = ClusterConfig::default().with_args(&args).unwrap();
        assert_eq!(c.replicas, 4);
        assert_eq!(c.routing, RoutingPolicy::RoundRobin);
        assert!(!c.steal);
        // defaults: single replica, prefix-affinity, stealing on
        let c = ClusterConfig::default();
        assert_eq!(c.replicas, 1);
        assert_eq!(c.routing, RoutingPolicy::Prefix);
        assert!(c.steal);
        // replicas are clamped to at least one
        let args = Args::parse("--replicas 0".split_whitespace().map(String::from));
        assert_eq!(ClusterConfig::default().with_args(&args).unwrap().replicas, 1);
        // unknown routing policy errors
        let args = Args::parse("--routing zigzag".split_whitespace().map(String::from));
        assert!(ClusterConfig::default().with_args(&args).is_err());
    }

    #[test]
    fn grid_has_vanilla_cr1_only() {
        let g = budget_grid(PolicyKind::Vanilla);
        assert!(g.iter().all(|p| p.cr == 1.0));
        let g = budget_grid(PolicyKind::Dms);
        assert!(g.iter().all(|p| p.cr > 1.0));
    }

    #[test]
    fn budget_label() {
        let p = BudgetPoint {
            max_len: 160,
            width: 4,
            cr: 8.0,
        };
        assert_eq!(p.label(), "160-4-8");
    }

    #[test]
    fn parse_tasks_validates() {
        assert!(parse_tasks(Some("math,aime"), &[]).is_ok());
        assert!(parse_tasks(Some("nope"), &[]).is_err());
        assert_eq!(parse_tasks(None, &["vt"]).unwrap(), vec!["vt"]);
    }
}
