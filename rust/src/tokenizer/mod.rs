//! Char-level tokenizer — the exact mirror of
//! `python/compile/tasks.py` (64-symbol vocabulary, 3 specials).
//!
//! The vocabulary order is load-bearing: token ids index the embedding
//! table of the AOT-compiled model. A runtime assertion cross-checks the
//! constructed vocabulary against the one recorded in
//! `artifacts/manifest.json`.

use anyhow::{bail, Result};

pub const PAD_ID: u32 = 0;
pub const BOS_ID: u32 = 1;
pub const EOS_ID: u32 = 2;
pub const VOCAB_SIZE: usize = 64;

/// Character list, identical to `tasks.CHARS` in Python.
pub const CHARS: &str = concat!(
    "0123456789",
    "abcdefghijklmnopqrstuvwxyz",
    "ABCD",
    "+-*=?",
    " \n.,:|#",
    "PUSHML",
    "QT%",
);

pub const SPECIALS: [&str; 3] = ["<pad>", "<bos>", "<eos>"];

/// Char-level tokenizer with O(1) encode via a 128-entry ASCII table.
pub struct Tokenizer {
    id_of: [i8; 128],
    char_of: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut id_of = [-1i8; 128];
        let mut char_of = Vec::with_capacity(VOCAB_SIZE);
        for (i, c) in CHARS.chars().enumerate() {
            debug_assert!((c as usize) < 128);
            id_of[c as usize] = (i + SPECIALS.len()) as i8;
            char_of.push(c);
        }
        assert_eq!(
            char_of.len() + SPECIALS.len(),
            VOCAB_SIZE,
            "vocabulary must have exactly {VOCAB_SIZE} symbols"
        );
        Self { id_of, char_of }
    }

    /// Encode text; errors on out-of-vocabulary symbols.
    pub fn encode(&self, text: &str) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(text.len());
        for c in text.chars() {
            let idx = if (c as usize) < 128 {
                self.id_of[c as usize]
            } else {
                -1
            };
            if idx < 0 {
                bail!("character {c:?} not in vocabulary");
            }
            out.push(idx as u32);
        }
        Ok(out)
    }

    /// Decode ids, skipping special tokens.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter_map(|&id| {
                let i = id as usize;
                if i < SPECIALS.len() || i >= VOCAB_SIZE {
                    None
                } else {
                    Some(self.char_of[i - SPECIALS.len()])
                }
            })
            .collect()
    }

    /// Full vocabulary (specials + chars), for manifest cross-checking.
    pub fn vocab(&self) -> Vec<String> {
        SPECIALS
            .iter()
            .map(|s| s.to_string())
            .chain(self.char_of.iter().map(|c| c.to_string()))
            .collect()
    }

    /// Verify against the vocabulary recorded in the manifest.
    pub fn check_manifest_vocab(&self, vocab: &[String]) -> Result<()> {
        let mine = self.vocab();
        if mine != vocab {
            bail!(
                "tokenizer vocabulary mismatch: rust={mine:?} manifest={vocab:?}"
            );
        }
        Ok(())
    }

    pub fn newline_id(&self) -> u32 {
        self.encode("\n").unwrap()[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_size_is_64() {
        let t = Tokenizer::new();
        assert_eq!(t.vocab().len(), 64);
    }

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let text = "Q:7+5-3*4=?\nT:7+5=2 A:B PUSH 3|MUL";
        let ids = t.encode(text).unwrap();
        assert_eq!(t.decode(&ids), text);
    }

    #[test]
    fn specials_skipped_on_decode() {
        let t = Tokenizer::new();
        let mut ids = vec![BOS_ID];
        ids.extend(t.encode("ab").unwrap());
        ids.push(EOS_ID);
        ids.push(PAD_ID);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn rejects_oov() {
        let t = Tokenizer::new();
        assert!(t.encode("hello!").is_err());
        assert!(t.encode("é").is_err());
    }

    #[test]
    fn digits_map_contiguously() {
        let t = Tokenizer::new();
        let ids = t.encode("0123456789").unwrap();
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(*id, 3 + i as u32);
        }
    }
}
