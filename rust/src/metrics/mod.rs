//! Metrics substrate: counters, gauges with peak tracking, histograms
//! with percentile queries, and a registry for report generation.

use std::collections::BTreeMap;

/// Monotone counter (f64 so fractional token-unit reads accumulate).
#[derive(Clone, Debug, Default)]
pub struct Counter {
    value: f64,
}

impl Counter {
    pub fn add(&mut self, x: f64) {
        self.value += x;
    }
    pub fn inc(&mut self) {
        self.value += 1.0;
    }
    pub fn get(&self) -> f64 {
        self.value
    }
    pub fn reset(&mut self) {
        self.value = 0.0;
    }
}

/// Gauge that remembers its peak — used for "peak tokens in memory".
#[derive(Clone, Debug, Default)]
pub struct PeakGauge {
    value: f64,
    peak: f64,
}

impl PeakGauge {
    pub fn set(&mut self, x: f64) {
        self.value = x;
        if x > self.peak {
            self.peak = x;
        }
    }
    pub fn add(&mut self, dx: f64) {
        self.set(self.value + dx);
    }
    pub fn get(&self) -> f64 {
        self.value
    }
    pub fn peak(&self) -> f64 {
        self.peak
    }
    pub fn reset(&mut self) {
        self.value = 0.0;
        self.peak = 0.0;
    }
}

/// Fixed-capacity sampling histogram with exact percentiles (stores all
/// samples up to `cap`, then reservoir-samples).
#[derive(Clone, Debug)]
pub struct Histogram {
    samples: Vec<f64>,
    count: u64,
    sum: f64,
    cap: usize,
    rng_state: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::with_capacity(16384)
    }
}

impl Histogram {
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            samples: Vec::new(),
            count: 0,
            sum: 0.0,
            cap,
            rng_state: 0x9E37_79B9,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // reservoir sampling keeps percentiles unbiased
            self.rng_state = self
                .rng_state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = (self.rng_state >> 11) % self.count;
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The retained samples, in recording order (the full stream below
    /// `cap`, the deterministic reservoir past it). Bit-exactness
    /// tests compare two runs through this accessor.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn reset(&mut self) {
        self.samples.clear();
        self.count = 0;
        self.sum = 0.0;
    }
}

/// Named-metric registry used by the engine and the server.
#[derive(Debug, Default)]
pub struct Registry {
    pub counters: BTreeMap<String, Counter>,
    pub gauges: BTreeMap<String, PeakGauge>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn counter(&mut self, name: &str) -> &mut Counter {
        self.counters.entry(name.to_string()).or_default()
    }
    pub fn gauge(&mut self, name: &str) -> &mut PeakGauge {
        self.gauges.entry(name.to_string()).or_default()
    }
    pub fn histogram(&mut self, name: &str) -> &mut Histogram {
        self.histograms.entry(name.to_string()).or_default()
    }

    /// Read-only view of a histogram's retained samples (empty when
    /// the histogram was never recorded to).
    pub fn histogram_samples(&self, name: &str) -> &[f64] {
        self.histograms.get(name).map_or(&[], |h| h.samples())
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in &self.counters {
            out.push_str(&format!("counter {name} = {:.3}\n", c.get()));
        }
        for (name, g) in &self.gauges {
            out.push_str(&format!(
                "gauge   {name} = {:.3} (peak {:.3})\n",
                g.get(),
                g.peak()
            ));
        }
        for (name, h) in &self.histograms {
            out.push_str(&format!(
                "hist    {name}: n={} mean={:.4} p50={:.4} p95={:.4} p99={:.4}\n",
                h.count(),
                h.mean(),
                h.percentile(50.0),
                h.percentile(95.0),
                h.percentile(99.0)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let mut c = Counter::default();
        c.add(2.5);
        c.inc();
        assert_eq!(c.get(), 3.5);
    }

    #[test]
    fn peak_gauge_tracks_max() {
        let mut g = PeakGauge::default();
        g.set(5.0);
        g.set(3.0);
        g.add(1.0);
        assert_eq!(g.get(), 4.0);
        assert_eq!(g.peak(), 5.0);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn histogram_reservoir_under_pressure() {
        let mut h = Histogram::with_capacity(100);
        for i in 0..10_000 {
            h.record((i % 100) as f64);
        }
        assert_eq!(h.count(), 10_000);
        let p50 = h.percentile(50.0);
        assert!(p50 > 20.0 && p50 < 80.0, "p50={p50}");
    }

    #[test]
    fn histogram_samples_are_deterministic_under_pressure() {
        let run = || {
            let mut h = Histogram::with_capacity(64);
            for i in 0..5000 {
                h.record((i * 7 % 997) as f64);
            }
            h.samples().to_vec()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.len(), 64);
        assert_eq!(a, b, "reservoir replacement is seeded, not random");
    }

    #[test]
    fn registry_exposes_samples_readonly() {
        let mut r = Registry::default();
        r.histogram("x").record(2.0);
        assert_eq!(r.histogram_samples("x"), &[2.0]);
        assert!(r.histogram_samples("missing").is_empty());
    }

    #[test]
    fn registry_report() {
        let mut r = Registry::default();
        r.counter("kv_reads").add(10.0);
        r.gauge("live_tokens").set(42.0);
        r.histogram("step_ms").record(1.5);
        let rep = r.report();
        assert!(rep.contains("kv_reads"));
        assert!(rep.contains("peak 42"));
    }
}
