//! Metrics substrate: counters, gauges with peak tracking, histograms
//! with percentile queries, and a registry for report generation.
//!
//! The registry renders three ways (see `docs/OBSERVABILITY.md`):
//! the human-oriented flat [`Registry::report`], the machine-readable
//! [`Registry::to_json`] snapshot behind `{"cmd": "stats"}`, and the
//! Prometheus text exposition [`Registry::prometheus`] (counters and
//! gauges as-is, histograms as summaries with p50/p95/p99 quantiles).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::Json;

/// Monotone counter (f64 so fractional token-unit reads accumulate).
#[derive(Clone, Debug, Default)]
pub struct Counter {
    value: f64,
}

impl Counter {
    pub fn add(&mut self, x: f64) {
        self.value += x;
    }
    pub fn inc(&mut self) {
        self.value += 1.0;
    }
    pub fn get(&self) -> f64 {
        self.value
    }
    pub fn reset(&mut self) {
        self.value = 0.0;
    }
}

/// Gauge that remembers its peak — used for "peak tokens in memory".
#[derive(Clone, Debug, Default)]
pub struct PeakGauge {
    value: f64,
    peak: f64,
}

impl PeakGauge {
    pub fn set(&mut self, x: f64) {
        self.value = x;
        if x > self.peak {
            self.peak = x;
        }
    }
    pub fn add(&mut self, dx: f64) {
        self.set(self.value + dx);
    }
    pub fn get(&self) -> f64 {
        self.value
    }
    pub fn peak(&self) -> f64 {
        self.peak
    }
    pub fn reset(&mut self) {
        self.value = 0.0;
        self.peak = 0.0;
    }
}

/// Fixed-capacity sampling histogram with exact percentiles (stores all
/// samples up to `cap`, then reservoir-samples).
#[derive(Clone, Debug)]
pub struct Histogram {
    samples: Vec<f64>,
    count: u64,
    sum: f64,
    cap: usize,
    rng_state: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::with_capacity(16384)
    }
}

impl Histogram {
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            samples: Vec::new(),
            count: 0,
            sum: 0.0,
            cap,
            rng_state: 0x9E37_79B9,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // reservoir sampling keeps percentiles unbiased
            self.rng_state = self
                .rng_state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = (self.rng_state >> 11) % self.count;
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The retained samples, in recording order (the full stream below
    /// `cap`, the deterministic reservoir past it). Bit-exactness
    /// tests compare two runs through this accessor.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile in [0, 100]. One-off convenience; callers querying
    /// several percentiles should use [`Histogram::percentiles`],
    /// which sorts the retained samples once.
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// Batch percentile query: one clone + sort of the retained
    /// samples regardless of how many percentiles are asked for.
    /// `total_cmp` ordering makes NaN samples sortable (they collate
    /// after +inf) instead of panicking the whole stats dump.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        ps.iter()
            .map(|p| {
                let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
                s[idx.min(s.len() - 1)]
            })
            .collect()
    }

    /// Sum of every recorded sample (not just the retained reservoir).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn reset(&mut self) {
        self.samples.clear();
        self.count = 0;
        self.sum = 0.0;
    }
}

/// Named-metric registry used by the engine and the server.
#[derive(Debug, Default)]
pub struct Registry {
    pub counters: BTreeMap<String, Counter>,
    pub gauges: BTreeMap<String, PeakGauge>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn counter(&mut self, name: &str) -> &mut Counter {
        self.counters.entry(name.to_string()).or_default()
    }
    pub fn gauge(&mut self, name: &str) -> &mut PeakGauge {
        self.gauges.entry(name.to_string()).or_default()
    }
    pub fn histogram(&mut self, name: &str) -> &mut Histogram {
        self.histograms.entry(name.to_string()).or_default()
    }

    /// Read-only view of a histogram's retained samples (empty when
    /// the histogram was never recorded to).
    pub fn histogram_samples(&self, name: &str) -> &[f64] {
        self.histograms.get(name).map_or(&[], |h| h.samples())
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in &self.counters {
            out.push_str(&format!("counter {name} = {:.3}\n", c.get()));
        }
        for (name, g) in &self.gauges {
            out.push_str(&format!(
                "gauge   {name} = {:.3} (peak {:.3})\n",
                g.get(),
                g.peak()
            ));
        }
        for (name, h) in &self.histograms {
            // one sort per histogram per report (not one per quantile)
            let p = h.percentiles(&[50.0, 95.0, 99.0]);
            out.push_str(&format!(
                "hist    {name}: n={} mean={:.4} p50={:.4} p95={:.4} p99={:.4}\n",
                h.count(),
                h.mean(),
                p[0],
                p[1],
                p[2]
            ));
        }
        out
    }

    /// Prometheus text exposition (version 0.0.4). Metric names are
    /// sanitized (`.` → `_`); an optional `(key, value)` label pair is
    /// attached to every sample — the cluster stats path labels each
    /// replica's block `replica="i"`. Histograms render as summaries:
    /// `quantile="0.5|0.95|0.99"` samples plus `_sum`/`_count`, with
    /// quantiles computed in one sort via [`Histogram::percentiles`].
    pub fn prometheus(&self, label: Option<(&str, &str)>) -> String {
        let base_label = |out: &mut String| {
            if let Some((k, v)) = label {
                let _ = write!(out, "{{{k}=\"{v}\"}}");
            }
        };
        let mut out = String::new();
        for (name, c) in &self.counters {
            let n = prom_name(name);
            let _ = writeln!(out, "# TYPE {n} counter");
            out.push_str(&n);
            base_label(&mut out);
            let _ = writeln!(out, " {}", prom_value(c.get()));
        }
        for (name, g) in &self.gauges {
            let n = prom_name(name);
            let _ = writeln!(out, "# TYPE {n} gauge");
            out.push_str(&n);
            base_label(&mut out);
            let _ = writeln!(out, " {}", prom_value(g.get()));
            let _ = writeln!(out, "# TYPE {n}_peak gauge");
            let _ = write!(out, "{n}_peak");
            base_label(&mut out);
            let _ = writeln!(out, " {}", prom_value(g.peak()));
        }
        for (name, h) in &self.histograms {
            let n = prom_name(name);
            let p = h.percentiles(&[50.0, 95.0, 99.0]);
            let _ = writeln!(out, "# TYPE {n} summary");
            for (q, v) in [("0.5", p[0]), ("0.95", p[1]), ("0.99", p[2])] {
                match label {
                    Some((k, lv)) => {
                        let _ = writeln!(
                            out,
                            "{n}{{{k}=\"{lv}\",quantile=\"{q}\"}} {}",
                            prom_value(v)
                        );
                    }
                    None => {
                        let _ =
                            writeln!(out, "{n}{{quantile=\"{q}\"}} {}", prom_value(v));
                    }
                }
            }
            let _ = write!(out, "{n}_sum");
            base_label(&mut out);
            let _ = writeln!(out, " {}", prom_value(h.sum()));
            let _ = write!(out, "{n}_count");
            base_label(&mut out);
            let _ = writeln!(out, " {}", h.count());
        }
        out
    }

    /// Machine-readable snapshot of every metric — the structured half
    /// of the `{"cmd": "stats"}` response. Histograms carry count,
    /// mean, and p50/p95/p99 (one sort each).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (name, c) in &self.counters {
            counters = counters.set(name, c.get());
        }
        let mut gauges = Json::obj();
        for (name, g) in &self.gauges {
            gauges = gauges.set(name, Json::obj().set("value", g.get()).set("peak", g.peak()));
        }
        let mut histograms = Json::obj();
        for (name, h) in &self.histograms {
            let p = h.percentiles(&[50.0, 95.0, 99.0]);
            histograms = histograms.set(
                name,
                Json::obj()
                    .set("count", h.count())
                    .set("mean", h.mean())
                    .set("sum", h.sum())
                    .set("p50", p[0])
                    .set("p95", p[1])
                    .set("p99", p[2]),
            );
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", histograms)
    }
}

/// Merge per-replica registry snapshots ([`Registry::to_json`]) into
/// one **valid** Prometheus exposition: the text format forbids
/// repeating a family's `# TYPE` line, so concatenating per-replica
/// expositions would be malformed — instead each family gets a single
/// TYPE line followed by one `label_key="block"`-labelled sample per
/// block. Used by the cluster router for `--prom-out` and the stats
/// endpoint's `prometheus` field.
pub fn prometheus_merge(label_key: &str, blocks: &[(String, Json)]) -> String {
    use std::collections::BTreeSet;
    let family_names = |kind: &str| -> BTreeSet<String> {
        let mut out = BTreeSet::new();
        for (_, j) in blocks {
            if let Some(pairs) = j.get(kind).and_then(Json::as_obj) {
                out.extend(pairs.iter().map(|(k, _)| k.clone()));
            }
        }
        out
    };
    let num = |j: &Json, kind: &str, name: &str, field: Option<&str>| -> Option<f64> {
        let m = j.get(kind)?.get(name)?;
        match field {
            Some(f) => m.get(f)?.as_f64(),
            None => m.as_f64(),
        }
    };
    let mut out = String::new();
    for name in family_names("counters") {
        let n = prom_name(&name);
        let _ = writeln!(out, "# TYPE {n} counter");
        for (label, j) in blocks {
            if let Some(v) = num(j, "counters", &name, None) {
                let _ = writeln!(out, "{n}{{{label_key}=\"{label}\"}} {}", prom_value(v));
            }
        }
    }
    for name in family_names("gauges") {
        let n = prom_name(&name);
        for (suffix, field) in [("", "value"), ("_peak", "peak")] {
            let _ = writeln!(out, "# TYPE {n}{suffix} gauge");
            for (label, j) in blocks {
                if let Some(v) = num(j, "gauges", &name, Some(field)) {
                    let _ = writeln!(
                        out,
                        "{n}{suffix}{{{label_key}=\"{label}\"}} {}",
                        prom_value(v)
                    );
                }
            }
        }
    }
    for name in family_names("histograms") {
        let n = prom_name(&name);
        let _ = writeln!(out, "# TYPE {n} summary");
        for (label, j) in blocks {
            for (q, field) in [("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99")] {
                if let Some(v) = num(j, "histograms", &name, Some(field)) {
                    let _ = writeln!(
                        out,
                        "{n}{{{label_key}=\"{label}\",quantile=\"{q}\"}} {}",
                        prom_value(v)
                    );
                }
            }
            if let Some(s) = num(j, "histograms", &name, Some("sum")) {
                let _ =
                    writeln!(out, "{n}_sum{{{label_key}=\"{label}\"}} {}", prom_value(s));
            }
            if let Some(c) = num(j, "histograms", &name, Some("count")) {
                let _ = writeln!(
                    out,
                    "{n}_count{{{label_key}=\"{label}\"}} {}",
                    prom_value(c)
                );
            }
        }
    }
    out
}

/// Sanitize a dotted metric name into the Prometheus charset
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`).
fn prom_name(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if s.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        s.insert(0, '_');
    }
    s
}

/// Deterministic sample formatting: integral values render without a
/// decimal point (matching the JSON writer), everything else uses
/// Rust's shortest-roundtrip `Display`.
fn prom_value(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let mut c = Counter::default();
        c.add(2.5);
        c.inc();
        assert_eq!(c.get(), 3.5);
    }

    #[test]
    fn peak_gauge_tracks_max() {
        let mut g = PeakGauge::default();
        g.set(5.0);
        g.set(3.0);
        g.add(1.0);
        assert_eq!(g.get(), 4.0);
        assert_eq!(g.peak(), 5.0);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn histogram_reservoir_under_pressure() {
        let mut h = Histogram::with_capacity(100);
        for i in 0..10_000 {
            h.record((i % 100) as f64);
        }
        assert_eq!(h.count(), 10_000);
        let p50 = h.percentile(50.0);
        assert!(p50 > 20.0 && p50 < 80.0, "p50={p50}");
    }

    #[test]
    fn histogram_samples_are_deterministic_under_pressure() {
        let run = || {
            let mut h = Histogram::with_capacity(64);
            for i in 0..5000 {
                h.record((i * 7 % 997) as f64);
            }
            h.samples().to_vec()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.len(), 64);
        assert_eq!(a, b, "reservoir replacement is seeded, not random");
    }

    #[test]
    fn registry_exposes_samples_readonly() {
        let mut r = Registry::default();
        r.histogram("x").record(2.0);
        assert_eq!(r.histogram_samples("x"), &[2.0]);
        assert!(r.histogram_samples("missing").is_empty());
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression: partial_cmp(..).unwrap() used to panic here — a
        // single NaN latency sample must never kill a stats dump
        let mut h = Histogram::default();
        h.record(1.0);
        h.record(f64::NAN);
        h.record(3.0);
        let p50 = h.percentile(50.0);
        assert!(p50.is_finite(), "NaN collates last, p50 stays finite");
        let r = {
            let mut reg = Registry::default();
            *reg.histogram("lat") = h;
            reg.report()
        };
        assert!(r.contains("lat"));
    }

    #[test]
    fn batch_percentiles_match_single_queries() {
        let mut h = Histogram::default();
        for i in (1..=100).rev() {
            h.record(i as f64);
        }
        let batch = h.percentiles(&[50.0, 95.0, 99.0]);
        assert_eq!(batch[0], h.percentile(50.0));
        assert_eq!(batch[1], h.percentile(95.0));
        assert_eq!(batch[2], h.percentile(99.0));
        assert_eq!(h.percentiles(&[]).len(), 0);
        assert_eq!(Histogram::default().percentiles(&[50.0]), vec![0.0]);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let mut r = Registry::default();
        r.counter("serve.requests").add(3.0);
        r.gauge("kv.live_fraction").set(0.5);
        r.histogram("serve.ttft_ms").record(2.0);
        let text = r.prometheus(None);
        assert!(text.contains("# TYPE serve_requests counter\nserve_requests 3\n"));
        assert!(text.contains("# TYPE kv_live_fraction gauge"));
        assert!(text.contains("serve_ttft_ms{quantile=\"0.5\"} 2"));
        assert!(text.contains("serve_ttft_ms_count 1"));
        let labelled = r.prometheus(Some(("replica", "1")));
        assert!(labelled.contains("serve_requests{replica=\"1\"} 3"));
        assert!(labelled.contains("serve_ttft_ms{replica=\"1\",quantile=\"0.5\"} 2"));
    }

    #[test]
    fn json_snapshot_carries_all_metric_kinds() {
        let mut r = Registry::default();
        r.counter("c").add(1.0);
        r.gauge("g").set(7.0);
        r.histogram("h").record(4.0);
        let j = r.to_json();
        assert_eq!(j.get("counters").unwrap().get("c").unwrap().as_f64(), Some(1.0));
        let g = j.get("gauges").unwrap().get("g").unwrap();
        assert_eq!(g.get("peak").unwrap().as_f64(), Some(7.0));
        let h = j.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64(), Some(1.0));
        assert_eq!(h.get("p99").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn merged_exposition_has_one_type_line_per_family() {
        let mk = |req: f64| {
            let mut r = Registry::default();
            r.counter("serve.requests").add(req);
            r.gauge("kv.live_fraction").set(0.25);
            r.histogram("serve.ttft_ms").record(req);
            r.to_json()
        };
        let blocks = vec![("0".to_string(), mk(3.0)), ("1".to_string(), mk(5.0))];
        let text = prometheus_merge("replica", &blocks);
        assert_eq!(text.matches("# TYPE serve_requests counter").count(), 1);
        assert!(text.contains("serve_requests{replica=\"0\"} 3"));
        assert!(text.contains("serve_requests{replica=\"1\"} 5"));
        assert_eq!(text.matches("# TYPE serve_ttft_ms summary").count(), 1);
        assert!(text.contains("serve_ttft_ms{replica=\"1\",quantile=\"0.5\"} 5"));
        assert!(text.contains("serve_ttft_ms_count{replica=\"0\"} 1"));
        assert!(text.contains("kv_live_fraction_peak{replica=\"0\"} 0.25"));
    }

    #[test]
    fn registry_report() {
        let mut r = Registry::default();
        r.counter("kv_reads").add(10.0);
        r.gauge("live_tokens").set(42.0);
        r.histogram("step_ms").record(1.5);
        let rep = r.report();
        assert!(rep.contains("kv_reads"));
        assert!(rep.contains("peak 42"));
    }
}
