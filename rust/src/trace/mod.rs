//! Structured tracing + flight recorder for the serving path.
//!
//! A [`Tracer`] is a fixed-capacity ring buffer of [`TraceEvent`]s,
//! each stamped with an integer-nanosecond timestamp and a monotone
//! sequence number. The timestamp is **caller-supplied** — the live
//! engine stamps wall time from a process-local anchor, [`SimEngine`]
//! stamps its logical tick counter, and the timeflow simulator stamps
//! sim time — so a deterministic producer yields a bit-identical
//! event stream on every same-seed run (the property CI asserts).
//!
//! Design contract (see `docs/OBSERVABILITY.md`):
//!
//! * **Zero-cost when disabled.** [`Tracer::disabled`] has capacity 0;
//!   [`Tracer::emit`] early-returns before touching the event, and the
//!   bench_serve traced-vs-untraced leg gates the overhead.
//! * **Bounded when enabled.** The ring never reallocates past its
//!   capacity; overwritten events are counted in
//!   [`Tracer::dropped`], never silently lost.
//! * **Per-request spans are derived, not stored.** The lifecycle
//!   events (`Submit → Admit → FirstToken → Finish`) carry a request
//!   id; [`RequestTrace::spans`] reconstructs the queue / prefill /
//!   decode spans from their stamps, and the Chrome trace-event export
//!   ([`chrome_trace_json`]) renders them as `"X"` duration events
//!   (Perfetto-loadable), everything else as `"i"` instants.
//!
//! [`SimEngine`]: crate::engine::SimEngine

use crate::util::Json;

/// Default flight-recorder capacity when tracing is enabled without an
/// explicit `--trace-events` override.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// One structured event on the serving path. Request-scoped variants
/// carry the request id ([`TraceEvent::request_id`]); cache and
/// cluster variants are batch/decision records.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// Request entered the admission queue.
    Submit {
        req: u64,
        prompt_tokens: usize,
        width: usize,
        prefix_hit_tokens: usize,
    },
    /// A chain of the request was installed on `lane`.
    Admit { req: u64, lane: usize },
    /// First generated token left the engine.
    FirstToken { req: u64 },
    /// The request's chains were evicted back to the queue.
    Preempt { req: u64, lane: usize },
    /// Request finished; totals are final [`ChainStats`] aggregates.
    ///
    /// [`ChainStats`]: crate::engine::ChainStats
    Finish {
        req: u64,
        gen_tokens: usize,
        read_tokens: f64,
        read_bytes: f64,
    },
    /// COW breaks that published page snapshots this tick.
    CowPublish { lane: usize, pages: u64 },
    /// Retained prefix pages restored into a lane at admission.
    PrefixRestore { req: u64, lane: usize, pages: usize, tokens: usize },
    /// Eviction/merge batch applied to a lane this tick, with the
    /// number of distinct (layer, head) cells touched.
    EvictBatch {
        lane: usize,
        evictions: u64,
        merges: u64,
        lh_touched: u64,
    },
    /// Pool payloads decoded into lane regions this tick
    /// (dequant-on-upload; an exact memcpy for f32 payloads).
    Dequant { lane: usize, pages: u64 },
    /// Router decision: request delivered to `replica`;
    /// `shadow_hit > 0` means affinity routing, not load.
    Route { req: u64, replica: usize, shadow_hit: usize },
    /// Work-steal round: queued requests migrated `from → to`.
    Steal { from: usize, to: usize, moved: usize },
    /// A replica died; the cluster keeps serving without it.
    ReplicaDead { replica: usize },
    /// A pipeline stage span (timeflow sim time): the event's stamp is
    /// the stage *end*; `start_ns` closes the interval.
    Stage {
        req: u64,
        replica: usize,
        stage: &'static str,
        start_ns: u64,
    },
    /// Request stamped with its SLO tier and absolute deadlines at
    /// arrival (before the admission decision).
    SloAssigned {
        req: u64,
        tier: &'static str,
        ttft_deadline_ns: u64,
        e2e_deadline_ns: u64,
    },
    /// Admission control turned the request away; it never ran.
    Rejected { req: u64 },
    /// A stamped deadline passed before the matching milestone
    /// (`kind` is `"ttft"` or `"e2e"`).
    DeadlineMiss { req: u64, kind: &'static str },
}

impl TraceEvent {
    /// Stable event name (the Chrome `name` field and the taxonomy key
    /// in `docs/OBSERVABILITY.md`).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Submit { .. } => "submit",
            TraceEvent::Admit { .. } => "admit",
            TraceEvent::FirstToken { .. } => "first_token",
            TraceEvent::Preempt { .. } => "preempt",
            TraceEvent::Finish { .. } => "finish",
            TraceEvent::CowPublish { .. } => "cow_publish",
            TraceEvent::PrefixRestore { .. } => "prefix_restore",
            TraceEvent::EvictBatch { .. } => "evict_batch",
            TraceEvent::Dequant { .. } => "dequant",
            TraceEvent::Route { .. } => "route",
            TraceEvent::Steal { .. } => "steal",
            TraceEvent::ReplicaDead { .. } => "replica_dead",
            TraceEvent::Stage { stage, .. } => stage,
            TraceEvent::SloAssigned { .. } => "slo_assigned",
            TraceEvent::Rejected { .. } => "rejected",
            TraceEvent::DeadlineMiss { .. } => "deadline_miss",
        }
    }

    /// Request id for request-scoped events.
    pub fn request_id(&self) -> Option<u64> {
        match *self {
            TraceEvent::Submit { req, .. }
            | TraceEvent::Admit { req, .. }
            | TraceEvent::FirstToken { req }
            | TraceEvent::Preempt { req, .. }
            | TraceEvent::Finish { req, .. }
            | TraceEvent::PrefixRestore { req, .. }
            | TraceEvent::Route { req, .. }
            | TraceEvent::Stage { req, .. }
            | TraceEvent::SloAssigned { req, .. }
            | TraceEvent::Rejected { req }
            | TraceEvent::DeadlineMiss { req, .. } => Some(req),
            _ => None,
        }
    }

    /// Lane index for lane-scoped events (the Chrome `tid`).
    pub fn lane(&self) -> Option<usize> {
        match *self {
            TraceEvent::Admit { lane, .. }
            | TraceEvent::Preempt { lane, .. }
            | TraceEvent::CowPublish { lane, .. }
            | TraceEvent::PrefixRestore { lane, .. }
            | TraceEvent::EvictBatch { lane, .. }
            | TraceEvent::Dequant { lane, .. } => Some(lane),
            _ => None,
        }
    }

    /// Event payload as a JSON object (the Chrome `args` field and the
    /// `{"cmd": "trace"}` response schema).
    pub fn args_json(&self) -> Json {
        match *self {
            TraceEvent::Submit {
                req,
                prompt_tokens,
                width,
                prefix_hit_tokens,
            } => Json::obj()
                .set("req", req)
                .set("prompt_tokens", prompt_tokens)
                .set("width", width)
                .set("prefix_hit_tokens", prefix_hit_tokens),
            TraceEvent::Admit { req, lane } => {
                Json::obj().set("req", req).set("lane", lane)
            }
            TraceEvent::FirstToken { req } => Json::obj().set("req", req),
            TraceEvent::Preempt { req, lane } => {
                Json::obj().set("req", req).set("lane", lane)
            }
            TraceEvent::Finish {
                req,
                gen_tokens,
                read_tokens,
                read_bytes,
            } => Json::obj()
                .set("req", req)
                .set("gen_tokens", gen_tokens)
                .set("kv_read_tokens", read_tokens)
                .set("kv_read_bytes", read_bytes),
            TraceEvent::CowPublish { lane, pages } => {
                Json::obj().set("lane", lane).set("pages", pages)
            }
            TraceEvent::PrefixRestore {
                req,
                lane,
                pages,
                tokens,
            } => Json::obj()
                .set("req", req)
                .set("lane", lane)
                .set("pages", pages)
                .set("tokens", tokens),
            TraceEvent::EvictBatch {
                lane,
                evictions,
                merges,
                lh_touched,
            } => Json::obj()
                .set("lane", lane)
                .set("evictions", evictions)
                .set("merges", merges)
                .set("lh_touched", lh_touched),
            TraceEvent::Dequant { lane, pages } => {
                Json::obj().set("lane", lane).set("pages", pages)
            }
            TraceEvent::Route {
                req,
                replica,
                shadow_hit,
            } => Json::obj()
                .set("req", req)
                .set("replica", replica)
                .set("shadow_hit", shadow_hit),
            TraceEvent::Steal { from, to, moved } => Json::obj()
                .set("from", from)
                .set("to", to)
                .set("moved", moved),
            TraceEvent::ReplicaDead { replica } => Json::obj().set("replica", replica),
            TraceEvent::Stage {
                req,
                replica,
                start_ns,
                ..
            } => Json::obj()
                .set("req", req)
                .set("replica", replica)
                .set("start_ns", start_ns),
            TraceEvent::SloAssigned {
                req,
                tier,
                ttft_deadline_ns,
                e2e_deadline_ns,
            } => Json::obj()
                .set("req", req)
                .set("tier", tier)
                .set("ttft_deadline_ns", ttft_deadline_ns)
                .set("e2e_deadline_ns", e2e_deadline_ns),
            TraceEvent::Rejected { req } => Json::obj().set("req", req),
            TraceEvent::DeadlineMiss { req, kind } => {
                Json::obj().set("req", req).set("kind", kind)
            }
        }
    }

    /// Parse the flat JSON form back into an event — the inverse of
    /// [`Stamped::to_json`], used by the cluster router to merge
    /// per-replica dump lines and by the schema round-trip tests.
    /// Returns `None` for unknown names or missing fields.
    pub fn from_json(name: &str, args: &Json) -> Option<TraceEvent> {
        let u = |k: &str| args.get(k).and_then(Json::as_usize);
        let id = |k: &str| args.get(k).and_then(Json::as_i64).map(|v| v as u64);
        let f = |k: &str| args.get(k).and_then(|x| x.as_f64());
        // stage spans reuse stage names ("decode", "dequant", …) that
        // collide with instant-event names; `start_ns` is unique to them
        if args.get("start_ns").is_some() {
            return Some(TraceEvent::Stage {
                req: id("req")?,
                replica: u("replica")?,
                stage: intern_stage(name)?,
                start_ns: id("start_ns")?,
            });
        }
        Some(match name {
            "submit" => TraceEvent::Submit {
                req: id("req")?,
                prompt_tokens: u("prompt_tokens")?,
                width: u("width")?,
                prefix_hit_tokens: u("prefix_hit_tokens")?,
            },
            "admit" => TraceEvent::Admit {
                req: id("req")?,
                lane: u("lane")?,
            },
            "first_token" => TraceEvent::FirstToken { req: id("req")? },
            "preempt" => TraceEvent::Preempt {
                req: id("req")?,
                lane: u("lane")?,
            },
            "finish" => TraceEvent::Finish {
                req: id("req")?,
                gen_tokens: u("gen_tokens")?,
                read_tokens: f("kv_read_tokens")?,
                read_bytes: f("kv_read_bytes")?,
            },
            "cow_publish" => TraceEvent::CowPublish {
                lane: u("lane")?,
                pages: id("pages")?,
            },
            "prefix_restore" => TraceEvent::PrefixRestore {
                req: id("req")?,
                lane: u("lane")?,
                pages: u("pages")?,
                tokens: u("tokens")?,
            },
            "evict_batch" => TraceEvent::EvictBatch {
                lane: u("lane")?,
                evictions: id("evictions")?,
                merges: id("merges")?,
                lh_touched: id("lh_touched")?,
            },
            "dequant" => TraceEvent::Dequant {
                lane: u("lane")?,
                pages: id("pages")?,
            },
            "route" => TraceEvent::Route {
                req: id("req")?,
                replica: u("replica")?,
                shadow_hit: u("shadow_hit")?,
            },
            "steal" => TraceEvent::Steal {
                from: u("from")?,
                to: u("to")?,
                moved: u("moved")?,
            },
            "replica_dead" => TraceEvent::ReplicaDead {
                replica: u("replica")?,
            },
            "slo_assigned" => TraceEvent::SloAssigned {
                req: id("req")?,
                tier: intern_tier(args.get("tier").and_then(Json::as_str)?)?,
                ttft_deadline_ns: id("ttft_deadline_ns")?,
                e2e_deadline_ns: id("e2e_deadline_ns")?,
            },
            "rejected" => TraceEvent::Rejected { req: id("req")? },
            "deadline_miss" => TraceEvent::DeadlineMiss {
                req: id("req")?,
                kind: intern_miss_kind(args.get("kind").and_then(Json::as_str)?)?,
            },
            _ => return None,
        })
    }
}

/// Map a parsed stage name back to the `&'static str` the timeflow
/// simulator emits (a closed set — see `Stage::name`).
fn intern_stage(name: &str) -> Option<&'static str> {
    ["dequant", "prefill", "first_token", "decode", "queue"]
        .into_iter()
        .find(|s| *s == name)
}

/// Map a parsed SLO tier name back to the `&'static str` emitted by
/// `SloTier::name` (a closed set).
fn intern_tier(name: &str) -> Option<&'static str> {
    ["interactive", "standard", "batch"]
        .into_iter()
        .find(|s| *s == name)
}

/// Map a parsed deadline-miss kind back to its `&'static str` form.
fn intern_miss_kind(name: &str) -> Option<&'static str> {
    ["ttft", "e2e"].into_iter().find(|s| *s == name)
}

/// A [`TraceEvent`] with its stamp: integer nanoseconds (wall, logical
/// tick, or sim time — the producer's clock) plus a monotone sequence
/// number that makes ordering total even within one stamp.
#[derive(Clone, Debug, PartialEq)]
pub struct Stamped {
    pub ts_ns: u64,
    pub seq: u64,
    pub event: TraceEvent,
}

impl Stamped {
    /// Flat JSON form (`{"cmd": "trace"}` responses and tests).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ts_ns", self.ts_ns)
            .set("seq", self.seq)
            .set("event", self.event.name())
            .set("args", self.event.args_json())
    }

    /// Inverse of [`Stamped::to_json`].
    pub fn from_json(j: &Json) -> Option<Stamped> {
        Some(Stamped {
            ts_ns: j.get("ts_ns").and_then(Json::as_i64)? as u64,
            seq: j.get("seq").and_then(Json::as_i64)? as u64,
            event: TraceEvent::from_json(
                j.get("event").and_then(Json::as_str)?,
                j.get("args")?,
            )?,
        })
    }
}

/// Fixed-capacity flight recorder (see module docs).
#[derive(Debug)]
pub struct Tracer {
    buf: Vec<Stamped>,
    /// Next ring slot to overwrite once the buffer is full.
    head: usize,
    capacity: usize,
    seq: u64,
    dropped: u64,
}

impl Tracer {
    /// The no-op sink: capacity 0, every emit returns immediately.
    pub fn disabled() -> Self {
        Self::ring(0)
    }

    /// A flight recorder holding the most recent `capacity` events.
    pub fn ring(capacity: usize) -> Self {
        Self {
            buf: Vec::new(),
            head: 0,
            capacity,
            seq: 0,
            dropped: 0,
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Record `event` at `ts_ns`. No-op (and allocation-free) when the
    /// tracer is disabled; overwrites the oldest event when full.
    #[inline]
    pub fn emit(&mut self, ts_ns: u64, event: TraceEvent) {
        if self.capacity == 0 {
            return;
        }
        let stamped = Stamped {
            ts_ns,
            seq: self.seq,
            event,
        };
        self.seq += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(stamped);
        } else {
            self.buf[self.head] = stamped;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    /// Events emitted over the tracer's lifetime (including dropped).
    pub fn recorded(&self) -> u64 {
        self.seq
    }

    /// Events overwritten by ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retained events in emission order (oldest first).
    pub fn events(&self) -> Vec<Stamped> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    /// Retained events of one request, in emission order.
    pub fn events_for(&self, req: u64) -> Vec<Stamped> {
        self.events()
            .into_iter()
            .filter(|s| s.event.request_id() == Some(req))
            .collect()
    }
}

/// Per-request view over a tracer's retained events.
pub struct RequestTrace {
    pub req: u64,
    pub events: Vec<Stamped>,
}

impl RequestTrace {
    /// Extract request `req` from a tracer.
    pub fn from_tracer(tracer: &Tracer, req: u64) -> Self {
        Self {
            req,
            events: tracer.events_for(req),
        }
    }

    /// Derived lifecycle spans `(name, start_ns, end_ns)`:
    /// `queue` = submit → first admit, `prefill` = first admit → first
    /// token, `decode` = first token → finish. Spans whose edges were
    /// dropped from the ring are omitted rather than guessed.
    pub fn spans(&self) -> Vec<(&'static str, u64, u64)> {
        let stamp_of = |pick: &dyn Fn(&TraceEvent) -> bool| {
            self.events.iter().find(|s| pick(&s.event)).map(|s| s.ts_ns)
        };
        let submit = stamp_of(&|e| matches!(e, TraceEvent::Submit { .. }));
        let admit = stamp_of(&|e| matches!(e, TraceEvent::Admit { .. }));
        let first = stamp_of(&|e| matches!(e, TraceEvent::FirstToken { .. }));
        let finish = stamp_of(&|e| matches!(e, TraceEvent::Finish { .. }));
        let mut out = Vec::new();
        if let (Some(a), Some(b)) = (submit, admit) {
            out.push(("queue", a, b));
        }
        if let (Some(a), Some(b)) = (admit, first) {
            out.push(("prefill", a, b));
        }
        if let (Some(a), Some(b)) = (first, finish) {
            out.push(("decode", a, b));
        }
        out
    }

    /// The request's events as a JSON array.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.events.iter().map(Stamped::to_json).collect())
    }
}

/// Render event groups as Chrome trace-event JSON (Perfetto-loadable).
/// Each `(pid, events)` group becomes one process — the cluster dump
/// passes one group per replica (+ one for the router). Derived
/// request spans and timeflow [`TraceEvent::Stage`] spans render as
/// `"X"` complete events; everything else as `"i"` instants.
/// Timestamps convert ns → µs (the Chrome unit); the mapping is pure,
/// so deterministic inputs serialize byte-identically.
pub fn chrome_trace_json(groups: &[(usize, Vec<Stamped>)]) -> String {
    let us = |ns: u64| Json::Num(ns as f64 / 1000.0);
    let mut out: Vec<Json> = Vec::new();
    for (pid, events) in groups {
        let pid = *pid as u64;
        // derived lifecycle spans, one track per lane-less request
        let mut req_ids: Vec<u64> =
            events.iter().filter_map(|s| s.event.request_id()).collect();
        req_ids.sort_unstable();
        req_ids.dedup();
        for req in req_ids {
            let rt = RequestTrace {
                req,
                events: events
                    .iter()
                    .filter(|s| s.event.request_id() == Some(req))
                    .cloned()
                    .collect(),
            };
            for (name, start, end) in rt.spans() {
                out.push(
                    Json::obj()
                        .set("name", name)
                        .set("cat", "request")
                        .set("ph", "X")
                        .set("ts", us(start))
                        .set("dur", us(end.saturating_sub(start)))
                        .set("pid", pid)
                        .set("tid", req)
                        .set("args", Json::obj().set("req", req)),
                );
            }
        }
        for s in events {
            if let TraceEvent::Stage { req, start_ns, .. } = s.event {
                out.push(
                    Json::obj()
                        .set("name", s.event.name())
                        .set("cat", "stage")
                        .set("ph", "X")
                        .set("ts", us(start_ns))
                        .set("dur", us(s.ts_ns.saturating_sub(start_ns)))
                        .set("pid", pid)
                        .set("tid", req)
                        .set("args", s.event.args_json()),
                );
            } else {
                out.push(
                    Json::obj()
                        .set("name", s.event.name())
                        .set("cat", "event")
                        .set("ph", "i")
                        .set("s", "t")
                        .set("ts", us(s.ts_ns))
                        .set("pid", pid)
                        .set("tid", s.event.lane().map(|l| l as u64).unwrap_or(0))
                        .set("args", s.event.args_json()),
                );
            }
        }
    }
    Json::obj()
        .set("traceEvents", Json::Arr(out))
        .set("displayTimeUnit", "ms")
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lifecycle(t: &mut Tracer, req: u64, base: u64) {
        t.emit(
            base,
            TraceEvent::Submit {
                req,
                prompt_tokens: 8,
                width: 1,
                prefix_hit_tokens: 0,
            },
        );
        t.emit(base + 10, TraceEvent::Admit { req, lane: 0 });
        t.emit(base + 30, TraceEvent::FirstToken { req });
        t.emit(
            base + 90,
            TraceEvent::Finish {
                req,
                gen_tokens: 6,
                read_tokens: 42.0,
                read_bytes: 5376.0,
            },
        );
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::disabled();
        lifecycle(&mut t, 1, 0);
        assert!(!t.enabled());
        assert_eq!(t.recorded(), 0);
        assert_eq!(t.dropped(), 0);
        assert!(t.events().is_empty());
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let mut t = Tracer::ring(3);
        for i in 0..5u64 {
            t.emit(i, TraceEvent::FirstToken { req: i });
        }
        assert_eq!(t.recorded(), 5);
        assert_eq!(t.dropped(), 2);
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        // oldest-first, the two oldest overwritten
        assert_eq!(evs[0].ts_ns, 2);
        assert_eq!(evs[2].ts_ns, 4);
        assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn request_spans_derive_from_lifecycle() {
        let mut t = Tracer::ring(64);
        lifecycle(&mut t, 7, 100);
        lifecycle(&mut t, 8, 200);
        let rt = RequestTrace::from_tracer(&t, 7);
        assert_eq!(rt.events.len(), 4);
        assert_eq!(
            rt.spans(),
            vec![("queue", 100, 110), ("prefill", 110, 130), ("decode", 130, 190)]
        );
        // a request with a dropped submit edge yields partial spans
        let rt8 = RequestTrace::from_tracer(&t, 8);
        assert_eq!(rt8.spans().len(), 3);
    }

    #[test]
    fn chrome_export_parses_and_is_deterministic() {
        let mut t = Tracer::ring(64);
        lifecycle(&mut t, 1, 1000);
        t.emit(1500, TraceEvent::CowPublish { lane: 2, pages: 3 });
        t.emit(
            2000,
            TraceEvent::Stage {
                req: 1,
                replica: 0,
                stage: "decode",
                start_ns: 1500,
            },
        );
        let groups = vec![(0usize, t.events())];
        let a = chrome_trace_json(&groups);
        let b = chrome_trace_json(&groups);
        assert_eq!(a, b, "pure function of the event stream");
        let j = Json::parse(&a).expect("valid JSON");
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 derived spans + 4 lifecycle instants + cow instant + stage X
        assert_eq!(evs.len(), 9);
        let phases: Vec<&str> = evs
            .iter()
            .map(|e| e.get("ph").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 4);
        for e in evs {
            assert!(e.get("ts").is_some() && e.get("pid").is_some());
        }
    }

    #[test]
    fn trace_event_json_round_trip() {
        let s = Stamped {
            ts_ns: 123,
            seq: 0,
            event: TraceEvent::Route {
                req: 9,
                replica: 2,
                shadow_hit: 96,
            },
        };
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("event").unwrap().as_str(), Some("route"));
        assert_eq!(
            parsed.get("args").unwrap().get("shadow_hit").unwrap().as_usize(),
            Some(96)
        );
    }

    #[test]
    fn every_variant_round_trips_through_json() {
        let variants = vec![
            TraceEvent::Submit {
                req: 1,
                prompt_tokens: 8,
                width: 2,
                prefix_hit_tokens: 4,
            },
            TraceEvent::Admit { req: 1, lane: 3 },
            TraceEvent::FirstToken { req: 1 },
            TraceEvent::Preempt { req: 1, lane: 3 },
            TraceEvent::Finish {
                req: 1,
                gen_tokens: 6,
                read_tokens: 42.5,
                read_bytes: 5440.0,
            },
            TraceEvent::CowPublish { lane: 2, pages: 5 },
            TraceEvent::PrefixRestore {
                req: 1,
                lane: 2,
                pages: 3,
                tokens: 48,
            },
            TraceEvent::EvictBatch {
                lane: 0,
                evictions: 7,
                merges: 2,
                lh_touched: 4,
            },
            TraceEvent::Dequant { lane: 1, pages: 2 },
            TraceEvent::Route {
                req: 1,
                replica: 2,
                shadow_hit: 96,
            },
            TraceEvent::Steal {
                from: 0,
                to: 1,
                moved: 4,
            },
            TraceEvent::ReplicaDead { replica: 1 },
            TraceEvent::Stage {
                req: 1,
                replica: 0,
                stage: "decode",
                start_ns: 500,
            },
            TraceEvent::SloAssigned {
                req: 1,
                tier: "interactive",
                ttft_deadline_ns: 20_000_000,
                e2e_deadline_ns: 50_000_000,
            },
            TraceEvent::Rejected { req: 1 },
            TraceEvent::DeadlineMiss { req: 1, kind: "e2e" },
        ];
        for (i, event) in variants.into_iter().enumerate() {
            let s = Stamped {
                ts_ns: 1000 + i as u64,
                seq: i as u64,
                event,
            };
            let line = s.to_json().to_string();
            let back = Stamped::from_json(&Json::parse(&line).unwrap())
                .unwrap_or_else(|| panic!("variant {i} failed to parse: {line}"));
            assert_eq!(back, s, "variant {i}");
        }
    }
}
