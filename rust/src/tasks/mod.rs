//! Synthetic benchmark suite — the exact mirror of
//! `python/compile/tasks.py` (see DESIGN.md §2 for the paper-task
//! mapping). Generators must match the Python implementation RNG-call
//! for RNG-call; `artifacts/tasks_golden.json` pins both.

mod generators;
mod suite;

pub use generators::{gen_arith, gen_code, gen_mcq, gen_niah, gen_vt};
pub use suite::{gen_niah_with_fillers, gen_problem, suite_names, Suite, SUITES};

use crate::util::SplitMix64;

/// A benchmark problem instance.
#[derive(Clone, Debug)]
pub struct Problem {
    pub task: String,
    /// Text fed to the model (after `<bos>`).
    pub prompt: String,
    /// Gold completion including the reasoning trace and final answer.
    pub solution: String,
    /// Canonical final answer for exact-match scoring.
    pub answer: String,
}

impl Problem {
    pub fn full_text(&self) -> String {
        format!("{}{}", self.prompt, self.solution)
    }
}

/// Final answer = text after the last `A:` marker up to newline/`|`.
/// Mirrors `tasks.extract_answer`.
pub fn extract_answer(text: &str) -> Option<String> {
    let idx = text.rfind("A:")?;
    let tail = &text[idx + 2..];
    let end = tail.find(['\n', '|']).unwrap_or(tail.len());
    let ans = tail[..end].trim();
    if ans.is_empty() {
        None
    } else {
        Some(ans.to_string())
    }
}

/// Seed the per-problem RNG exactly as the Python mirror does.
pub(crate) fn problem_rng(seed: u64, index: u64) -> SplitMix64 {
    SplitMix64::new(
        seed.wrapping_mul(0x517C_C1B7_2722_0A95)
            .wrapping_add(index.wrapping_mul(2).wrapping_add(1)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_answer_basic() {
        assert_eq!(extract_answer("7+5=2 A:2\n"), Some("2".into()));
        assert_eq!(extract_answer("x A:B\nmore"), Some("B".into()));
        assert_eq!(extract_answer("no answer"), None);
        assert_eq!(extract_answer("A: \n"), None);
    }

    #[test]
    fn extract_answer_takes_last_marker() {
        // MCQ prompts contain "A:<digit>" as an option; the final answer
        // marker must win.
        assert_eq!(
            extract_answer("Q:1+1=? A:4 B:2 ... A:B\n"),
            Some("B".into())
        );
    }
}
