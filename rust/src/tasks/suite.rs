//! Suite presets — mirrors `tasks.SUITES` and `tasks.gen_problem`.

use super::{gen_arith, gen_code, gen_mcq, gen_niah, gen_vt, problem_rng, Problem};

/// Which generator a suite uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// arith with (lo, hi) op-count band
    Arith(usize, usize),
    /// mcq with (lo, hi) op-count band
    Mcq(usize, usize),
    /// code with (lo, hi) instruction band
    Code(usize, usize),
    /// niah with (lo, hi) filler band
    Niah(usize, usize),
    /// vt with chain band and noise band
    Vt(usize, usize, usize, usize),
}

/// Name → preset. Order/bands mirror `tasks.SUITES` exactly.
pub const SUITES: &[(&str, Suite)] = &[
    ("math", Suite::Arith(3, 6)),    // MATH 500 analog (easy band)
    ("aime", Suite::Arith(8, 13)),   // AIME 24 analog (hard band)
    ("gpqa", Suite::Mcq(4, 8)),
    ("lcb", Suite::Code(6, 10)),
    ("gsm8k", Suite::Arith(4, 8)),   // ablation probe band
    ("niah", Suite::Niah(3, 5)),
    ("vt", Suite::Vt(3, 6, 4, 8)),
    ("mmlu", Suite::Mcq(2, 5)),      // Table-1 short-context analogs
    ("hellaswag", Suite::Code(3, 6)),
];

pub fn suite_names() -> Vec<&'static str> {
    SUITES.iter().map(|(n, _)| *n).collect()
}

fn lookup(task: &str) -> Option<Suite> {
    SUITES
        .iter()
        .find(|(n, _)| *n == task)
        .map(|(_, s)| *s)
}

/// Generate problem `index` of suite `task` — deterministic and
/// identical across languages.
pub fn gen_problem(task: &str, seed: u64, index: u64) -> Problem {
    let mut rng = problem_rng(seed, index);
    let suite = lookup(task).unwrap_or_else(|| panic!("unknown suite '{task}'"));
    let mut p = match suite {
        Suite::Arith(lo, hi) => {
            let n = lo + rng.below(hi - lo + 1);
            gen_arith(&mut rng, n)
        }
        Suite::Mcq(lo, hi) => {
            let n = lo + rng.below(hi - lo + 1);
            gen_mcq(&mut rng, n)
        }
        Suite::Code(lo, hi) => {
            let n = lo + rng.below(hi - lo + 1);
            gen_code(&mut rng, n)
        }
        Suite::Niah(lo, hi) => {
            let n = lo + rng.below(hi - lo + 1);
            gen_niah(&mut rng, n)
        }
        Suite::Vt(clo, chi, nlo, nhi) => {
            let n_chain = clo + rng.below(chi - clo + 1);
            let n_noise = nlo + rng.below(nhi - nlo + 1);
            gen_vt(&mut rng, n_chain, n_noise)
        }
    };
    p.task = task.to_string();
    p
}

/// NIAH with an explicit filler count — used by the Table 2 context-
/// length extrapolation experiment (the suite band is bypassed but the
/// seeding scheme is unchanged).
pub fn gen_niah_with_fillers(seed: u64, index: u64, n_fillers: usize) -> Problem {
    let mut rng = problem_rng(seed, index);
    let mut p = gen_niah(&mut rng, n_fillers);
    p.task = "niah".into();
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let a = gen_problem("aime", 7, 3);
        let b = gen_problem("aime", 7, 3);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.solution, b.solution);
    }

    #[test]
    fn different_indices_differ() {
        let a = gen_problem("math", 7, 0);
        let b = gen_problem("math", 7, 1);
        assert_ne!(a.prompt, b.prompt);
    }

    #[test]
    fn all_suites_generate() {
        for name in suite_names() {
            let p = gen_problem(name, 1, 0);
            assert!(p.prompt.starts_with("Q:"), "{name}");
            assert!(!p.answer.is_empty(), "{name}");
        }
    }

    #[test]
    fn hard_band_is_longer_than_easy() {
        let easy: usize = (0..20)
            .map(|i| gen_problem("math", 5, i).solution.len())
            .sum();
        let hard: usize = (0..20)
            .map(|i| gen_problem("aime", 5, i).solution.len())
            .sum();
        assert!(hard > easy);
    }
}
