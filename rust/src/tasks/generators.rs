//! Problem generators. Every RNG call must mirror
//! `python/compile/tasks.py` exactly (same order, same modulus) so both
//! languages generate identical problems from identical seeds.

use super::Problem;
use crate::util::SplitMix64;

const OPS: [char; 3] = ['+', '-', '*'];

fn apply(op: char, a: i64, b: i64) -> i64 {
    match op {
        '+' => (a + b).rem_euclid(10),
        '-' => (a - b).rem_euclid(10),
        _ => (a * b).rem_euclid(10),
    }
}

/// Modular-arithmetic chain-of-thought (MATH 500 / AIME 24 analog).
pub fn gen_arith(rng: &mut SplitMix64, n_ops: usize) -> Problem {
    let mut vals = vec![rng.below(10) as i64];
    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        ops.push(OPS[rng.below(3)]);
        vals.push(rng.below(10) as i64);
    }
    let mut expr = vals[0].to_string();
    for (o, v) in ops.iter().zip(&vals[1..]) {
        expr.push(*o);
        expr.push_str(&v.to_string());
    }
    let mut acc = vals[0];
    let mut steps = Vec::with_capacity(n_ops);
    for (o, v) in ops.iter().zip(&vals[1..]) {
        let nxt = apply(*o, acc, *v);
        steps.push(format!("{acc}{o}{v}={nxt}"));
        acc = nxt;
    }
    Problem {
        task: "arith".into(),
        prompt: format!("Q:{expr}=?\nT:"),
        solution: format!("{} A:{acc}\n", steps.join(" ")),
        answer: acc.to_string(),
    }
}

/// 4-choice MCQ over an arithmetic chain (GPQA Diamond analog).
pub fn gen_mcq(rng: &mut SplitMix64, n_ops: usize) -> Problem {
    let base = gen_arith(rng, n_ops);
    let correct: i64 = base.answer.parse().unwrap();
    let mut opts = vec![correct];
    while opts.len() < 4 {
        let d = rng.below(10) as i64;
        if !opts.contains(&d) {
            opts.push(d);
        }
    }
    // deterministic Fisher–Yates, same iteration order as Python
    for i in (1..=3usize).rev() {
        let j = rng.below(i + 1);
        opts.swap(i, j);
    }
    let letters = ['A', 'B', 'C', 'D'];
    let pos = opts.iter().position(|&o| o == correct).unwrap();
    let letter = letters[pos];
    // strip "Q:" and "=?\nT:" from the arithmetic prompt
    let expr = &base.prompt[2..base.prompt.len() - 5];
    let opt_str = letters
        .iter()
        .zip(&opts)
        .map(|(l, o)| format!("{l}:{o}"))
        .collect::<Vec<_>>()
        .join(" ");
    let steps = &base.solution[..base.solution.rfind(" A:").unwrap()];
    Problem {
        task: "mcq".into(),
        prompt: format!("Q:{expr}=? {opt_str}\nT:"),
        solution: format!("{steps} A:{letter}\n"),
        answer: letter.to_string(),
    }
}

const CODE_OPS: [&str; 3] = ["ADD", "MUL", "SUB"];

/// Stack-machine trace task (LiveCodeBench analog, scored pass@all).
pub fn gen_code(rng: &mut SplitMix64, n_instr: usize) -> Problem {
    let mut instrs: Vec<String> = Vec::with_capacity(n_instr);
    let mut stack: Vec<i64> = Vec::new();
    let mut trace: Vec<String> = Vec::with_capacity(n_instr);
    for _ in 0..n_instr {
        if stack.len() < 2 || rng.below(2) == 0 {
            let d = rng.below(10) as i64;
            instrs.push(format!("PUSH {d}"));
            stack.push(d);
        } else {
            let op = CODE_OPS[rng.below(3)];
            let b = stack.pop().unwrap();
            let a = stack.pop().unwrap();
            let r = match op {
                "ADD" => (a + b).rem_euclid(10),
                "MUL" => (a * b).rem_euclid(10),
                _ => (a - b).rem_euclid(10),
            };
            stack.push(r);
            instrs.push(op.to_string());
        }
        trace.push(stack.iter().map(|v| v.to_string()).collect::<String>());
    }
    let ans = stack.last().unwrap().to_string();
    Problem {
        task: "code".into(),
        prompt: format!("Q:{}\nT:", instrs.join("|")),
        solution: format!("{} A:{ans}\n", trace.join(" ")),
        answer: ans,
    }
}

const NOUNS: [&str; 8] = [
    "bird", "fish", "tree", "leaf", "rock", "star", "frog", "moon",
];
const VERBS: [&str; 6] = ["saw", "ate", "hid", "made", "took", "lost"];

fn filler(rng: &mut SplitMix64) -> String {
    format!(
        "the {} {} a {}.",
        NOUNS[rng.below(8)],
        VERBS[rng.below(6)],
        NOUNS[rng.below(8)]
    )
}

/// Needle in a haystack (RULER NIAH analog).
pub fn gen_niah(rng: &mut SplitMix64, n_fillers: usize) -> Problem {
    let vars = ['u', 'v', 'w', 'x', 'y', 'z'];
    let var = vars[rng.below(6)];
    let val = rng.below(10);
    let pos = rng.below(n_fillers + 1);
    let mut parts = Vec::with_capacity(n_fillers + 1);
    for i in 0..=n_fillers {
        if i == pos {
            parts.push(format!("key {var}={val}."));
        } else {
            parts.push(filler(rng));
        }
    }
    Problem {
        task: "niah".into(),
        prompt: format!("Q:{} ?{var}\nT:", parts.join(" ")),
        solution: format!("A:{val}\n"),
        answer: val.to_string(),
    }
}

/// Variable tracking (RULER VT analog).
pub fn gen_vt(rng: &mut SplitMix64, n_chain: usize, n_noise: usize) -> Problem {
    let mut pool: Vec<char> = "abcdefghijklmnopqrst".chars().collect();
    rng.shuffle(&mut pool);
    let chain: Vec<char> = pool[..n_chain + 1].to_vec();
    let noise: Vec<char> = pool[n_chain + 1..n_chain + 1 + n_noise].to_vec();
    let val = rng.below(10);
    let mut stmts = vec![format!("{}={val}", chain[0])];
    for i in 1..chain.len() {
        stmts.push(format!("{}={}", chain[i], chain[i - 1]));
    }
    for v in &noise {
        stmts.push(format!("{v}={}", rng.below(10)));
    }
    // deterministic shuffle of statement order (excluding the first),
    // then restore the chain statements' relative order.
    let mut order: Vec<usize> = (1..stmts.len()).collect();
    rng.shuffle(&mut order);
    let chain_positions: Vec<usize> = order
        .iter()
        .enumerate()
        .filter(|(_, idx)| **idx >= 1 && **idx <= n_chain)
        .map(|(k, _)| k)
        .collect();
    let mut chain_sorted: Vec<usize> = order
        .iter()
        .copied()
        .filter(|idx| *idx >= 1 && *idx <= n_chain)
        .collect();
    chain_sorted.sort_unstable();
    for (k, idx) in chain_positions.iter().zip(chain_sorted) {
        order[*k] = idx;
    }
    let mut body = vec![stmts[0].clone()];
    body.extend(order.iter().map(|&i| stmts[i].clone()));
    let target = if n_chain > 0 { chain[n_chain] } else { chain[0] };
    Problem {
        task: "vt".into(),
        prompt: format!("Q:{}. ?{target}\nT:", body.join(". ")),
        solution: format!("A:{val}\n"),
        answer: val.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::extract_answer;

    fn rng() -> SplitMix64 {
        SplitMix64::new(12345)
    }

    #[test]
    fn arith_answer_matches_trace() {
        for seed in 0..20u64 {
            let mut r = SplitMix64::new(seed);
            let p = gen_arith(&mut r, 5);
            assert_eq!(extract_answer(&p.solution), Some(p.answer.clone()));
            // answer is a digit mod 10
            let a: i64 = p.answer.parse().unwrap();
            assert!((0..10).contains(&a));
        }
    }

    #[test]
    fn mcq_letter_points_at_correct_option() {
        for seed in 0..20u64 {
            let mut r = SplitMix64::new(seed);
            let p = gen_mcq(&mut r, 4);
            assert!(["A", "B", "C", "D"].contains(&p.answer.as_str()));
            // the option labelled with the answer letter equals the
            // arithmetic result encoded in the trace's last step
            let needle = format!("{}:", p.answer);
            assert!(p.prompt.contains(&needle));
        }
    }

    #[test]
    fn code_trace_is_consistent() {
        let mut r = rng();
        let p = gen_code(&mut r, 8);
        assert_eq!(extract_answer(&p.solution), Some(p.answer.clone()));
        assert!(p.prompt.starts_with("Q:PUSH"));
    }

    #[test]
    fn niah_key_is_present_once() {
        let mut r = rng();
        let p = gen_niah(&mut r, 6);
        assert_eq!(p.prompt.matches("key ").count(), 1);
    }

    #[test]
    fn vt_has_expected_statements() {
        let mut r = rng();
        let p = gen_vt(&mut r, 4, 5);
        // 1 root + 4 chain + 5 noise assignments
        assert_eq!(p.prompt.matches('=').count(), 10);
    }
}
