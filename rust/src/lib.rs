//! # hyperscale — Inference-Time Hyper-Scaling with KV Cache Compression
//!
//! Rust coordinator (Layer 3) of the three-layer reproduction of
//! *"Inference-Time Hyper-Scaling with KV Cache Compression"* (Łańcucki
//! et al., 2025). The compute graph (Layer 2, JAX) and the attention
//! hot-spots (Layer 1, Pallas) are AOT-compiled at build time into HLO
//! text artifacts that this crate loads and executes through the PJRT
//! CPU client (`xla` crate). Python never runs on the request path.
//!
//! Major subsystems (see `docs/ARCHITECTURE.md` for the full data
//! flow and `docs/POLICIES.md` for the policy zoo):
//!
//! * [`runtime`]  — PJRT client, artifact manifest, executable wrappers;
//! * [`kvcache`]  — paged per-(layer, KV-head) slot cache with live-mask
//!   accounting (KV reads / peak tokens — the paper's §5.1 metrics);
//! * [`compress`] — the policy zoo: DMS (delayed eviction), TOVA, H2O,
//!   Quest, DMC merging, sliding window, vanilla;
//! * [`engine`]   — continuous-batching scheduler (dynamic admission,
//!   preemption), step-batch assembly, sampler, majority-voting /
//!   pass@all aggregation;
//! * [`scaling`]  — L-W-CR budget controller + Pareto-frontier analysis
//!   (App. E margin integrals);
//! * [`analysis`] — App. G analytical latency model (Fig. 7);
//! * [`experiments`] — one driver per paper figure/table;
//! * [`server`]   — TCP line-JSON serving front end: single engine or
//!   a multi-replica cluster behind a prefix-aware router;
//! * [`trace`], [`metrics`] — observability: flight-recorder tracing
//!   (per-request spans, cache/router events, Perfetto export) and the
//!   metric registry with Prometheus/JSON exposition
//!   (`docs/OBSERVABILITY.md`);
//! * [`tasks`], [`tokenizer`] — synthetic benchmark suite, mirrored
//!   byte-for-byte with `python/compile/tasks.py`.

// The cache/executor code indexes multi-dimensional flat arrays by
// design (the executor ABI is flat); iterator rewrites of those loops
// obscure the layout arithmetic. Style lints that fight that idiom are
// opted out crate-wide; correctness lints stay on (-D warnings in CI).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args
)]

pub mod analysis;
pub mod compress;
pub mod config;
pub mod engine;
pub mod experiments;
pub mod kvcache;
pub mod metrics;
pub mod runtime;
pub mod scaling;
pub mod server;
pub mod tasks;
pub mod tokenizer;
pub mod trace;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
