//! Analytical models and report rendering.

pub mod latency_model;
pub mod tables;

pub use latency_model::{Accelerator, LatencyModel, LlamaClass, H100};
