//! Plain-text table rendering for experiment reports (EXPERIMENTS.md).

/// A simple aligned-columns table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                if c.len() > widths[i] {
                    widths[i] = c.len();
                }
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let empty = String::new();
                let c = cells.get(i).unwrap_or(&empty);
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a fraction as percent with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Format a float compactly.
pub fn num(x: f64) -> String {
    if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 10.0 {
        format!("{:.1}", x)
    } else {
        format!("{:.2}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(&["method", "acc"]);
        t.row(vec!["dms".into(), "62.5".into()]);
        t.row(vec!["vanilla".into(), "50.0".into()]);
        let md = t.markdown();
        assert!(md.starts_with("| method"));
        assert!(md.contains("| dms "));
        assert_eq!(md.lines().count(), 4);
    }

    #[test]
    fn formats() {
        assert_eq!(pct(0.625), "62.5");
        assert_eq!(num(12345.6), "12346");
        assert_eq!(num(42.34), "42.3");
        assert_eq!(num(1.234), "1.23");
    }
}
