//! Appendix G: the share of inference latency attributable to KV cache
//! reads — implemented exactly with the paper's constants (Fig. 7).
//!
//! FLOPS(B, L) ≈ n·B·(6·d·d_ff + 4·d² + 4·d·d_kv + 4·d·L) + 2·B·d·V   (Eq. 2)
//! Reads(B, L) ≈ n·(6·d·d_ff + 4·d² + 4·d·d_kv + 4·B·L·d_kv)·2 + 2·d·V·2
//!
//! (Eq. 3 in the paper is written with an implicit 2 bytes/param for
//! 16-bit weights; we carry the factor explicitly. The paper's sanity
//! check Reads(1,0)/2 ≈ 7.5B parameters holds — tested below.)
//!
//! The KV term carries its own bytes-per-element factor, separate from
//! the weight precision: with quantized page payloads
//! ([`KvDtype`](crate::kvcache::KvDtype), docs/NUMERICS.md) the cache
//! is read at ~1 byte/element (q8) or ~0.5 (q4) plus per-row
//! scale/zero-point overhead, while weights stay bf16. Configure it
//! with [`LatencyModel::with_kv_dtype`]; the memory-reads axis of the
//! Pareto analysis then reflects what quantized payloads actually pull
//! from memory.

/// Hardware peak numbers (NVIDIA H100 SXM, BF16 dense).
#[derive(Clone, Copy, Debug)]
pub struct Accelerator {
    pub flops_per_s: f64,
    pub bytes_per_s: f64,
}

/// H100 SXM: 989.5 TFLOPS bf16, 3.35 TB/s HBM.
pub const H100: Accelerator = Accelerator {
    flops_per_s: 989.5e12,
    bytes_per_s: 3.35e12,
};

/// Transformer shape parameters (App. G table).
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    /// number of layers n
    pub n_layers: f64,
    /// hidden dim d
    pub d_model: f64,
    /// MLP internal dim d_ff
    pub d_ff: f64,
    /// key/value dim d_kv (per layer, all KV heads)
    pub d_kv: f64,
    /// vocabulary size V
    pub vocab: f64,
    /// bytes per element of weights/activations (2 for bf16)
    pub bytes: f64,
    /// bytes per element of the KV cache (defaults to `bytes`; lower
    /// under quantized payloads — see [`LatencyModel::with_kv_dtype`])
    pub kv_bytes: f64,
}

/// Preset model classes used by Fig. 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LlamaClass {
    Llama8B,
    Qwen1_5B,
    Qwen7B,
    Qwen32B,
}

impl LatencyModel {
    /// Llama 3.1 8B constants from App. G.
    pub fn llama31_8b() -> Self {
        Self {
            n_layers: 32.0,
            d_model: 4096.0,
            d_ff: 14336.0,
            d_kv: 1024.0,
            vocab: 128256.0,
            bytes: 2.0,
            kv_bytes: 2.0,
        }
    }

    pub fn preset(class: LlamaClass) -> Self {
        match class {
            LlamaClass::Llama8B => Self::llama31_8b(),
            // Qwen 2.5 configs (GQA): d_kv = n_kv_heads * head_dim
            LlamaClass::Qwen1_5B => Self {
                n_layers: 28.0,
                d_model: 1536.0,
                d_ff: 8960.0,
                d_kv: 256.0,
                vocab: 151936.0,
                bytes: 2.0,
                kv_bytes: 2.0,
            },
            LlamaClass::Qwen7B => Self {
                n_layers: 28.0,
                d_model: 3584.0,
                d_ff: 18944.0,
                d_kv: 512.0,
                vocab: 152064.0,
                bytes: 2.0,
                kv_bytes: 2.0,
            },
            LlamaClass::Qwen32B => Self {
                n_layers: 64.0,
                d_model: 5120.0,
                d_ff: 27648.0,
                d_kv: 1024.0,
                vocab: 152064.0,
                bytes: 2.0,
                kv_bytes: 2.0,
            },
        }
    }

    /// Set the KV-cache read precision from a payload dtype: effective
    /// bytes/element = per-row storage (codes + scale/zero-point) ÷
    /// `head_dim`. Note [`KvDtype::F32`](crate::kvcache::KvDtype)
    /// yields 4.0 — what this repo's host store pays for exact
    /// payloads — while the presets default to the paper's 2.0 (bf16).
    pub fn with_kv_dtype(mut self, dtype: crate::kvcache::KvDtype, head_dim: usize) -> Self {
        self.kv_bytes = dtype.row_payload_bytes(head_dim) as f64 / head_dim as f64;
        self
    }

    /// Eq. 2: FLOPs of one auto-regressive step.
    pub fn flops(&self, batch: f64, seq: f64) -> f64 {
        let per_layer = 6.0 * self.d_model * self.d_ff
            + 4.0 * self.d_model * self.d_model
            + 4.0 * self.d_model * self.d_kv
            + 4.0 * self.d_model * seq;
        self.n_layers * batch * per_layer + 2.0 * batch * self.d_model * self.vocab
    }

    /// Eq. 3: bytes read from HBM for one step. The paper's
    /// coefficients (6·d·d_ff etc.) already include the 2 bytes/param
    /// factor — e.g. 6·d·d_ff = (3·d·d_ff params)·(2 bytes); we write
    /// that as param-count × `bytes` to stay precision-generic, and
    /// price the KV term at `kv_bytes` so quantized cache payloads are
    /// reflected without touching the weight precision.
    pub fn reads(&self, batch: f64, seq: f64) -> f64 {
        let params_per_layer = 3.0 * self.d_model * self.d_ff
            + 2.0 * self.d_model * self.d_model
            + 2.0 * self.d_model * self.d_kv;
        (self.n_layers * params_per_layer + self.d_model * self.vocab) * self.bytes
            + self.kv_reads(batch, seq)
    }

    /// Bytes read for the KV cache alone (the paper's 4·n·B·L·d_kv
    /// term — 2 tensors × `kv_bytes` bytes/element).
    pub fn kv_reads(&self, batch: f64, seq: f64) -> f64 {
        self.n_layers * 2.0 * batch * seq * self.d_kv * self.kv_bytes
    }

    /// Eq. 6: step latency assuming ideal compute/memory overlap.
    pub fn step_latency(&self, acc: &Accelerator, batch: f64, seq: f64) -> f64 {
        let t_compute = self.flops(batch, seq) / acc.flops_per_s;
        let t_memory = self.reads(batch, seq) / acc.bytes_per_s;
        t_compute.max(t_memory)
    }

    /// Fig. 7: fraction of step latency attributable to KV-cache reads
    /// when the cache is compressed by `cr`.
    pub fn kv_latency_fraction(&self, acc: &Accelerator, batch: f64, seq: f64, cr: f64) -> f64 {
        self.fraction_at_eff_seq(acc, batch, seq, seq / cr)
    }

    /// Fig. 7 under a per-(layer, head)
    /// [`BudgetPlan`](crate::compress::BudgetPlan): the KV read term is priced at
    /// the plan's aggregate resident tokens (mean per head, capped at
    /// the dense length) instead of the scalar `seq / cr`. A uniform
    /// plan at budget `seq / cr` reproduces
    /// [`LatencyModel::kv_latency_fraction`] exactly; non-uniform
    /// plans land at the same point when they conserve the global
    /// budget — what this model makes visible is how a plan's *total*,
    /// not its shape, sets the memory-bound latency share.
    pub fn kv_latency_fraction_planned(
        &self,
        acc: &Accelerator,
        batch: f64,
        seq: f64,
        plan: &crate::compress::BudgetPlan,
        layers: usize,
        kv_heads: usize,
    ) -> f64 {
        let cells = (layers * kv_heads).max(1) as f64;
        let eff_seq = (plan.total(layers, kv_heads) as f64 / cells).min(seq);
        self.fraction_at_eff_seq(acc, batch, seq, eff_seq)
    }

    /// Host-side seconds per token to promote a cold-tier block:
    /// per-token payload bytes over the upload bandwidth, plus the
    /// host dequant throughput when the cold dtype is quantized — the
    /// same regime the engine's `kv.dequant_us` gauge measures on the
    /// real promote path.
    pub fn cold_promote_s_per_token(
        &self,
        dtype: crate::kvcache::KvDtype,
        head_dim: usize,
        upload_bytes_per_s: f64,
        dequant_bytes_per_s: f64,
    ) -> f64 {
        let rows = self.n_layers * (self.d_kv / head_dim as f64) * 2.0;
        let bytes = rows * dtype.row_payload_bytes(head_dim) as f64;
        let mut s = bytes / upload_bytes_per_s;
        if dtype.is_quantized() {
            s += bytes / dequant_bytes_per_s;
        }
        s
    }

    /// TTFT of a prompt whose first `hit_tokens` are covered by the
    /// cold tier: promote (upload + dequant) the covered tokens,
    /// prefill only the uncached tail, then one decode step. With
    /// `hit_tokens = 0` this degenerates to the full-prefill TTFT a
    /// cold miss pays, so the difference between the two calls is the
    /// cold tier's TTFT dividend.
    #[allow(clippy::too_many_arguments)]
    pub fn cold_hit_ttft_s(
        &self,
        acc: &Accelerator,
        dtype: crate::kvcache::KvDtype,
        head_dim: usize,
        hit_tokens: usize,
        prompt_tokens: usize,
        upload_bytes_per_s: f64,
        dequant_bytes_per_s: f64,
    ) -> f64 {
        assert!(hit_tokens <= prompt_tokens);
        let prefill_per_tok = self.flops(1.0, prompt_tokens as f64) / acc.flops_per_s;
        let promote = hit_tokens as f64
            * self.cold_promote_s_per_token(
                dtype,
                head_dim,
                upload_bytes_per_s,
                dequant_bytes_per_s,
            );
        let tail = (prompt_tokens - hit_tokens) as f64 * prefill_per_tok;
        promote + tail + self.step_latency(acc, 1.0, prompt_tokens as f64)
    }

    fn fraction_at_eff_seq(
        &self,
        acc: &Accelerator,
        batch: f64,
        seq: f64,
        eff_seq: f64,
    ) -> f64 {
        let t_kv = self.kv_reads(batch, eff_seq) / acc.bytes_per_s;
        let t_total = {
            let t_compute = self.flops(batch, seq) / acc.flops_per_s;
            let reads_other = self.reads(batch, 0.0);
            let t_memory = (reads_other + self.kv_reads(batch, eff_seq)) / acc.bytes_per_s;
            t_compute.max(t_memory)
        };
        t_kv / t_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appendix_g_parameter_sanity() {
        // "Reads(1,0)/2 ≈ 7.5B approximates the parameter count"
        let m = LatencyModel::llama31_8b();
        let params = m.reads(1.0, 0.0) / 2.0;
        assert!(
            (params - 7.5e9).abs() < 0.2e9,
            "Reads(1,0)/2 = {params:.3e}, expected ~7.5e9"
        );
    }

    #[test]
    fn appendix_g_flops_coefficients() {
        // Eq. 4 prints "1.45e9·B + 5.24e5·B·L"; the base term is a
        // typo for ~1.45e10 (an 8B-param model needs ≈ 2·7.5e9 FLOPs
        // per token — consistent with the paper's own Eq. 2 and the
        // exact slope 4·d·n = 5.24e5). We assert the formula, not the
        // typo.
        let m = LatencyModel::llama31_8b();
        let base = m.flops(1.0, 0.0);
        assert!((base - 1.50e10).abs() < 0.1e10, "base {base:.3e}");
        let slope = m.flops(1.0, 1000.0) - base;
        assert!((slope / 1000.0 - 5.24e5).abs() < 0.1e5);
    }

    #[test]
    fn appendix_g_reads_coefficients() {
        // Eq. 5: Reads(B, L) ≈ 1.50e10 + 1.31e5·B·L  (bytes)
        let m = LatencyModel::llama31_8b();
        let base = m.reads(1.0, 0.0);
        assert!((base - 1.50e10).abs() < 0.05e10, "base {base:.3e}");
        let slope = m.reads(4.0, 1000.0) - base;
        assert!((slope / 4000.0 - 1.31e5).abs() < 0.1e5);
    }

    #[test]
    fn kv_fraction_grows_with_batch_and_length() {
        let m = LatencyModel::llama31_8b();
        let f_small = m.kv_latency_fraction(&H100, 1.0, 1024.0, 1.0);
        let f_big = m.kv_latency_fraction(&H100, 256.0, 32768.0, 1.0);
        assert!(f_small < 0.2);
        assert!(f_big > 0.9, "f_big = {f_big}");
    }

    #[test]
    fn compression_reduces_kv_fraction() {
        let m = LatencyModel::llama31_8b();
        let f1 = m.kv_latency_fraction(&H100, 64.0, 16384.0, 1.0);
        let f4 = m.kv_latency_fraction(&H100, 64.0, 16384.0, 4.0);
        let f8 = m.kv_latency_fraction(&H100, 64.0, 16384.0, 8.0);
        assert!(f1 > f4 && f4 > f8);
    }

    #[test]
    fn quantized_kv_dtype_scales_only_the_kv_term() {
        use crate::kvcache::KvDtype;
        let hd = 64;
        let base = LatencyModel::llama31_8b();
        let q8 = LatencyModel::llama31_8b().with_kv_dtype(KvDtype::Q8, hd);
        let q4 = LatencyModel::llama31_8b().with_kv_dtype(KvDtype::Q4, hd);
        // weight reads untouched (seq = 0 has no KV term)
        assert_eq!(base.reads(4.0, 0.0), q8.reads(4.0, 0.0));
        // kv reads scale with the per-element storage cost:
        // bf16 2.0 → q8 (64+5)/64 ≈ 1.078 → q4 (32+5)/64 ≈ 0.578
        let r = |m: &LatencyModel| m.kv_reads(64.0, 8192.0);
        assert!((r(&base) / r(&q8) - 2.0 / (69.0 / 64.0)).abs() < 1e-9);
        assert!((r(&base) / r(&q4) - 2.0 / (37.0 / 64.0)).abs() < 1e-9);
        // and the KV latency share falls accordingly
        let f = |m: &LatencyModel| m.kv_latency_fraction(&H100, 64.0, 16384.0, 1.0);
        assert!(f(&base) > f(&q8) && f(&q8) > f(&q4));
        // f32 host payloads cost MORE than the bf16 paper default
        let f32m = LatencyModel::llama31_8b().with_kv_dtype(KvDtype::F32, hd);
        assert!((f32m.kv_bytes - 4.0).abs() < 1e-12);
    }

    #[test]
    fn planned_fraction_matches_scalar_cr_for_uniform_plans() {
        use crate::compress::BudgetPlan;
        let m = LatencyModel::llama31_8b();
        let (batch, seq) = (64.0, 16384.0);
        // uniform plan at seq/4 per head == scalar CR 4
        let uni = BudgetPlan::uniform(4096);
        let f_plan = m.kv_latency_fraction_planned(&H100, batch, seq, &uni, 2, 2);
        let f_cr = m.kv_latency_fraction(&H100, batch, seq, 4.0);
        assert!((f_plan - f_cr).abs() < 1e-12);
        // a skewed plan conserving the same total lands at the same
        // share — the budget axis is plan-aggregate bytes
        let skewed = BudgetPlan::per_head(2, 2, vec![8192, 4096, 2048, 2048]);
        let f_skew = m.kv_latency_fraction_planned(&H100, batch, seq, &skewed, 2, 2);
        assert!((f_skew - f_cr).abs() < 1e-12);
        // a bigger total → bigger memory share
        let rich = BudgetPlan::uniform(8192);
        let f_rich = m.kv_latency_fraction_planned(&H100, batch, seq, &rich, 2, 2);
        assert!(f_rich > f_plan);
    }

    #[test]
    fn cold_hit_ttft_beats_reprefill_and_degenerates_at_zero_hit() {
        use crate::kvcache::KvDtype;
        let m = LatencyModel::llama31_8b();
        let (up, dq) = (64e9, 8e9); // PCIe-class upload, host dequant
        let hd = 64;
        // a covered prompt: promote + tail prefill < full re-prefill
        let hit = m.cold_hit_ttft_s(&H100, KvDtype::Q4, hd, 1008, 1024, up, dq);
        let miss = m.cold_hit_ttft_s(&H100, KvDtype::Q4, hd, 0, 1024, up, dq);
        assert!(hit < miss, "cold hit {hit:.6}s vs re-prefill {miss:.6}s");
        // zero hit tokens is exactly prefill + one decode step
        let per_tok = m.flops(1.0, 1024.0) / H100.flops_per_s;
        let expect = 1024.0 * per_tok + m.step_latency(&H100, 1.0, 1024.0);
        assert!((miss - expect).abs() < 1e-15);
        // promote cost orders by payload size within the quantized
        // path, and stays well under the prefill it replaces
        let p = |d: KvDtype| m.cold_promote_s_per_token(d, hd, up, dq);
        assert!(p(KvDtype::Q4) < p(KvDtype::Q8));
        assert!(p(KvDtype::Q4) < per_tok, "promote must beat prefill");
    }

    #[test]
    fn paper_claim_batch256_share() {
        // §5.1: for batch 256 and 8K–32K contexts, the KV-read share
        // exceeds 90% for Qwen-R1 1.5B and 80% for Qwen-R1 7B.
        let q15 = LatencyModel::preset(LlamaClass::Qwen1_5B);
        let q7 = LatencyModel::preset(LlamaClass::Qwen7B);
        assert!(q15.kv_latency_fraction(&H100, 256.0, 8192.0, 1.0) > 0.9);
        assert!(q7.kv_latency_fraction(&H100, 256.0, 8192.0, 1.0) > 0.8);
    }
}
