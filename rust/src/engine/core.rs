//! Engine core: PJRT executor plumbing around the continuous-batching
//! scheduler.
//!
//! The engine owns the *data plane* — weights, compiled executables,
//! the KV [`CacheStore`], the tokenizer — and drives the control plane
//! in [`super::scheduler`] one *tick* at a time. A tick admits pending
//! chains into idle lanes (optionally preempting under cache pressure),
//! then issues at most one prefill chunk and one decode step covering
//! every active lane, so freshly admitted requests prefill while older
//! requests keep decoding. Batches are assembled and the per-lane host
//! work parallelized by [`super::batch`].
//!
//! Two entry points sit on top of the tick loop:
//!
//! * [`Engine::run`] — classic static batch: submit everything, tick
//!   until drained (all existing callers);
//! * [`Engine::begin_session`] / [`Engine::submit`] / [`Engine::tick`]
//!   — dynamic admission for the server: requests join and retire
//!   mid-run, and each completion carries queueing/TTFT timing.

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::batch;
use super::scheduler::{
    ChainState, CompletedRequest, Phase, Scheduler, SchedulerConfig,
};
use super::sequence::{ChainResult, FinishReason, GenRequest, GenResult, SubmitSpec};
use super::slo::SloTier;
use crate::compress::{
    build_allocator, build_policy_planned, per_head_budget, AllocatorKind,
    BudgetAllocator, Policy, PolicyKind, StepView, WriteAction,
};
use crate::config::EngineConfig;
use crate::kvcache::{CacheStore, ColdTier, Geometry, PageId, RadixPrefixIndex};
use crate::metrics::Registry;
use crate::runtime::{Executor, ParamBuffers, Runtime, Weights};
use crate::tokenizer::{Tokenizer, BOS_ID, EOS_ID, PAD_ID};
use crate::trace::{Stamped, TraceEvent, Tracer};

/// Aggregate engine statistics for a `run` call / serving session.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Decode steps issued to the executor.
    pub decode_steps: u64,
    /// Prefill chunks issued to the executor.
    pub prefill_chunks: u64,
    /// Seconds spent inside executor calls.
    pub executor_s: f64,
    /// Seconds spent per tick end-to-end (includes `executor_s`).
    pub host_s: f64,
    /// Siblings that reused a leader's prefill via cache fork.
    pub forks: u64,
    /// Chains preempted back into the queue under cache pressure.
    pub preemptions: u64,
    /// Scheduler ticks that did executor work.
    pub ticks: u64,
    /// Prompt tokens restored from the radix prefix cache instead of
    /// being prefilled.
    pub prefix_hit_tokens: u64,
}

/// One continuous-batching run: the scheduler plus its accumulated
/// statistics. Created by [`Engine::begin_session`]; requests enter via
/// [`Engine::submit`] and leave through the completions returned by
/// [`Engine::tick`].
pub struct Session {
    sched: Scheduler,
    stats: EngineStats,
}

impl Session {
    /// Statistics accumulated so far.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Chains waiting for a lane.
    pub fn queue_depth(&self) -> usize {
        self.sched.queue_depth()
    }

    /// Lanes currently running a chain.
    pub fn active_lanes(&self) -> usize {
        self.sched.active_lanes()
    }

    /// Whole queued requests eligible for work-stealing handoff (no
    /// chain installed, completed, or resumed) — the cluster router's
    /// occupancy probe, alongside `queue_depth`/`active_lanes`.
    pub fn stealable_requests(&self) -> usize {
        self.sched.stealable_requests()
    }
}

/// The inference engine: one executor batch + policy + metrics.
pub struct Engine {
    /// PJRT runtime (client, manifest, artifact loaders).
    pub runtime: Runtime,
    /// Engine configuration this instance was built with.
    pub cfg: EngineConfig,
    /// Tokenizer shared with the Python exporter.
    pub tokenizer: Tokenizer,
    /// Serving metrics registry (counters / gauges / histograms).
    pub metrics: Registry,
    geom: Geometry,
    weights: Rc<Weights>,
    /// Device-resident parameters (buffered-exec fast path).
    param_bufs: Option<ParamBuffers>,
    decode_exec: Executor,
    prefill_exec: Executor,
    cache: CacheStore,
    /// Radix index over clean prompt pages retained from completed
    /// requests (prefix-cache admission).
    prefix_index: RadixPrefixIndex,
    /// Cold tier of the prefix cache: pages the hot index LRU-trims
    /// are demoted here as compressed blocks (q4 by default) instead
    /// of freed, and promoted back on a covering lookup
    /// (`--cold-tier-bytes` / `--cold-dtype` / `--spill-dir`).
    cold: ColdTier,
    /// Budget allocator shaping each chain's per-(layer, head) plan
    /// (`--allocator`); adaptive re-plans from lane-local `AttnStats`.
    allocator: Box<dyn BudgetAllocator>,
    /// Flight recorder (`--trace-events`); the no-op sink when tracing
    /// is disabled (see docs/OBSERVABILITY.md).
    tracer: Tracer,
    /// Wall-clock anchor: trace stamps are integer nanoseconds since
    /// engine construction.
    trace_epoch: Instant,
    /// ticket → external request id (the cluster router's
    /// client-visible id) for trace-event keying.
    trace_ids: BTreeMap<u64, u64>,
    /// Read tokens accumulated by the tick in flight, flushed into the
    /// `kv.read_tokens` / `kv.read_bytes` counters each tick.
    tick_read_tokens: f64,
    /// Retrofit metadata of the loaded variant.
    window: usize,
    immediate: bool,
    dms_variant: bool,
    newline_id: u32,
}

impl Engine {
    /// Open artifacts, load the variant's weights, compile executables.
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let runtime = Runtime::open(&cfg.artifacts)?;
        let tokenizer = Tokenizer::new();
        tokenizer.check_manifest_vocab(&runtime.manifest.vocab)?;

        let vmeta = runtime
            .manifest
            .variants
            .get(&cfg.variant)
            .ok_or_else(|| anyhow!("variant '{}' missing from manifest", cfg.variant))?
            .clone();
        let dms_variant = vmeta.alpha_mode.starts_with("dms");
        let weights = runtime.load_weights(&cfg.variant)?;

        let dname = runtime.decode_exe_name(cfg.batch, cfg.slots, cfg.use_jnp_decode)?;
        let dmeta = runtime.manifest.executables[&dname].clone();
        let decode_exec = Executor::new(runtime.load_executable(&dname)?, dmeta);

        // prefill flavour follows the variant (DMS window/immediate) and
        // whether the engine policy exploits sparsity during prefill.
        let use_dms_prefill = dms_variant
            && matches!(cfg.policy, PolicyKind::Dms | PolicyKind::DmsImmediate);
        let pname = runtime.prefill_exe_name(
            cfg.batch,
            cfg.slots,
            vmeta.window,
            vmeta.immediate,
            use_dms_prefill,
        )?;
        let pmeta = runtime.manifest.executables[&pname].clone();
        let prefill_exec = Executor::new(runtime.load_executable(&pname)?, pmeta);

        let geom = runtime.manifest.cache_geometry(cfg.slots);
        // pool-owned payloads (COW snapshots, prefix-retained pages)
        // are stored under the configured dtype; lane regions and
        // executor uploads stay f32 (see docs/NUMERICS.md)
        let mut cache = CacheStore::with_dtype(geom, cfg.batch, cfg.kv_dtype);
        let tracer = Tracer::ring(cfg.trace_events);
        // the store's per-tick event counters exist only for the
        // flight recorder — keep them off (zero-cost) when untraced
        cache.set_event_tracking(tracer.enabled());
        let prefix_index = RadixPrefixIndex::new(geom.page_size);
        let cold = ColdTier::new(
            cfg.cold_tier_bytes,
            cfg.cold_dtype,
            cfg.spill_dir.clone(),
            geom.head_dim,
        );
        let newline_id = tokenizer.newline_id();
        let param_bufs = if cfg.buffered_exec {
            Some(ParamBuffers::from_weights(&runtime.client, &weights)?)
        } else {
            None
        };
        let allocator = build_allocator(cfg.allocator);
        Ok(Self {
            runtime,
            tokenizer,
            metrics: Registry::default(),
            geom,
            weights,
            param_bufs,
            decode_exec,
            prefill_exec,
            cache,
            prefix_index,
            cold,
            allocator,
            tracer,
            trace_epoch: Instant::now(),
            trace_ids: BTreeMap::new(),
            tick_read_tokens: 0.0,
            window: vmeta.window,
            immediate: vmeta.immediate,
            dms_variant,
            cfg,
            newline_id,
        })
    }

    /// Cache geometry of the loaded executables.
    pub fn geometry(&self) -> Geometry {
        self.geom
    }

    /// Eviction-delay window of the loaded variant (the clamp floor of
    /// the App. F.1 per-head budget; what `build_chain_policy` passes
    /// to [`per_head_budget`]).
    pub fn variant_window(&self) -> usize {
        self.window
    }

    /// Switch the compression policy (+ CR) without recompiling the
    /// decode executable; the prefill flavour is re-selected (cached).
    /// Retained prefixes are flushed: they encode the old policy's
    /// prefill behaviour.
    pub fn set_policy(&mut self, kind: PolicyKind, cr: f64) -> Result<()> {
        self.cfg.policy = kind;
        self.cfg.cr = cr;
        self.flush_prefix_cache();
        self.reload_prefill()
    }

    /// Switch the model variant (weights + retrofit metadata).
    pub fn set_variant(&mut self, variant: &str) -> Result<()> {
        let vmeta = self
            .runtime
            .manifest
            .variants
            .get(variant)
            .ok_or_else(|| anyhow!("variant '{variant}' missing from manifest"))?
            .clone();
        self.cfg.variant = variant.to_string();
        self.weights = self.runtime.load_weights(variant)?;
        self.param_bufs = if self.cfg.buffered_exec {
            Some(ParamBuffers::from_weights(&self.runtime.client, &self.weights)?)
        } else {
            None
        };
        self.window = vmeta.window;
        self.immediate = vmeta.immediate;
        self.dms_variant = vmeta.alpha_mode.starts_with("dms");
        // retained prefixes hold the previous variant's K/V values
        self.flush_prefix_cache();
        self.reload_prefill()
    }

    /// Release every retained prefix page (policy/variant switch) and
    /// drop the cold tier with it — demoted blocks encode the old
    /// policy's prefill behaviour just like hot pages do.
    fn flush_prefix_cache(&mut self) {
        for id in self.prefix_index.release_all() {
            self.cache.release_page(id);
        }
        self.cold.clear();
        self.metrics.gauge("kv.prefix_pages_retained").set(0.0);
        self.metrics.gauge("kv.prefix_retained_bytes").set(0.0);
        self.metrics.gauge("kv.cold_tier_bytes").set(0.0);
        self.metrics.gauge("kv.spilled_bytes").set(0.0);
    }

    fn reload_prefill(&mut self) -> Result<()> {
        let use_dms_prefill = self.dms_variant
            && matches!(
                self.cfg.policy,
                PolicyKind::Dms | PolicyKind::DmsImmediate
            );
        let pname = self.runtime.prefill_exe_name(
            self.cfg.batch,
            self.cfg.slots,
            self.window,
            self.immediate,
            use_dms_prefill,
        )?;
        let pmeta = self.runtime.manifest.executables[&pname].clone();
        self.prefill_exec = Executor::new(self.runtime.load_executable(&pname)?, pmeta);
        Ok(())
    }

    /// Metrics snapshot for the server's stats endpoint.
    pub fn metrics_report(&self) -> String {
        self.metrics.report()
    }

    // ------------------------------------------------------------------
    // Observability (see docs/OBSERVABILITY.md)
    // ------------------------------------------------------------------

    /// Integer-ns timestamp on the engine's trace clock (wall time
    /// since construction).
    fn now_ns(&self) -> u64 {
        self.trace_epoch.elapsed().as_nanos() as u64
    }

    /// External request id a ticket's trace events are keyed by — the
    /// cluster's client-visible id when one was attached at submit,
    /// otherwise the ticket itself.
    fn trace_req(&self, ticket: u64) -> u64 {
        self.trace_ids.get(&ticket).copied().unwrap_or(ticket)
    }

    /// The engine's flight recorder (trace queries and dumps).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Retained trace events of one request id, in emission order.
    pub fn trace_events_for(&self, req: u64) -> Vec<Stamped> {
        self.tracer.events_for(req)
    }

    /// Full-model K+V payload bytes one cached token costs under the
    /// store's dtype: per-(layer, head) payload bytes × pair count.
    /// This prices `ChainStats` read tokens (means over pairs) into
    /// the `kv_read_bytes` the paper's x-axis measures.
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.cache.payload_bytes_per_token() * self.geom.lh() as f64
    }

    /// Quest page budget for a step (scalar for the whole batch — the
    /// decode executable takes one `k`; the largest active `max_len`
    /// sets it).
    fn quest_k(&self, max_len: usize) -> i32 {
        if self.cfg.policy == PolicyKind::Quest {
            let budget = (max_len as f64 / self.cfg.cr).ceil() as usize;
            (budget.div_ceil(self.geom.page_size)).max(1) as i32
        } else {
            self.geom.pages() as i32
        }
    }

    /// App. F.1 global budget for a chain: per-head rule × cells.
    fn global_budget(&self, max_len: usize) -> usize {
        per_head_budget(self.cfg.cr, max_len, self.window) * self.geom.lh()
    }

    /// Build a chain's policy with its admission-time budget plan. The
    /// uniform allocator reproduces the legacy scalar budget exactly
    /// (equal per-head split of the same global); adaptive chains
    /// start from the uniform fallback (no stats yet) and re-plan as
    /// decode statistics accrue.
    fn build_chain_policy(&self, max_len: usize) -> Box<dyn Policy> {
        let plan = self.allocator.plan(
            self.geom.layers,
            self.geom.kv_heads,
            self.global_budget(max_len),
            None,
        );
        build_policy_planned(self.cfg.policy, plan, self.window, self.geom.page_size)
    }

    // ------------------------------------------------------------------
    // Session API (dynamic admission)
    // ------------------------------------------------------------------

    /// Start a serving session with default scheduling (FCFS admission,
    /// no preemption).
    pub fn begin_session(&self) -> Session {
        self.begin_session_with(SchedulerConfig::default())
    }

    /// Start a serving session with explicit scheduler configuration.
    pub fn begin_session_with(&self, scfg: SchedulerConfig) -> Session {
        Session {
            sched: Scheduler::new(self.cfg.batch, scfg),
            stats: EngineStats::default(),
        }
    }

    /// Tokenize, validate, and enqueue one request; returns the ticket
    /// that identifies it in [`Engine::tick`] completions. Invalid
    /// requests fail here without affecting in-flight work.
    pub fn submit(&mut self, session: &mut Session, req: &GenRequest) -> Result<u64> {
        self.submit_traced(session, req, None)
    }

    /// [`Engine::submit`] with an external request id attached: trace
    /// events for the request are keyed by `trace_id` (the cluster
    /// router's client-visible id) instead of the engine-local ticket.
    pub fn submit_traced(
        &mut self,
        session: &mut Session,
        req: &GenRequest,
        trace_id: Option<u64>,
    ) -> Result<u64> {
        let mut ids = vec![BOS_ID];
        ids.extend(self.tokenizer.encode(&req.prompt)?);
        if ids.len() + 2 > req.max_len {
            bail!(
                "prompt ({} tokens) does not fit max_len {}",
                ids.len(),
                req.max_len
            );
        }
        if req.max_len > self.geom.slots {
            bail!(
                "max_len {} exceeds slot capacity {}",
                req.max_len,
                self.geom.slots
            );
        }
        // prefix-cache admission: match the prompt against retained
        // prefixes; on a hit every chain of the request carries the
        // matched pages (one pool reference per page while queued) and
        // will start prefill at the divergence point.
        let mut prefix_pages: Vec<u64> = Vec::new();
        let mut prefix_tokens = 0usize;
        if self.cfg.prefix_cache {
            self.metrics.counter("kv.prefix_lookups").inc();
            let mut hit = self.prefix_index.lookup(&ids);
            // cold tier: probe for demoted pages extending the hot hit
            // and promote them back into the pool. A promoted page is
            // re-indexed and flows into the ordinary hit below — its
            // extra cost is one dequant-on-upload at restore time, not
            // a re-prefill.
            if self.cold.enabled() {
                let promoted = self.promote_cold_hits(&ids, hit.tokens);
                if promoted > 0 {
                    self.metrics.counter("kv.cold_hits").inc();
                    self.metrics
                        .counter("kv.cold_hit_tokens")
                        .add((promoted * self.geom.page_size) as f64);
                    hit = self.prefix_index.lookup(&ids);
                }
            }
            if hit.tokens > 0 {
                self.metrics.counter("kv.prefix_hits").inc();
                self.metrics
                    .counter("kv.prefix_hit_tokens")
                    .add(hit.tokens as f64);
                for _ in 0..req.width.max(1) {
                    for &id in &hit.pages {
                        self.cache.retain_page(id);
                    }
                }
                prefix_pages = hit.pages;
                prefix_tokens = hit.tokens;
            }
        }
        let prompt_tokens = ids.len();
        let ticket =
            session
                .sched
                .submit_with_prefix(req, Arc::new(ids), &prefix_pages, prefix_tokens);
        if self.tracer.enabled() {
            let rid = trace_id.unwrap_or(ticket);
            self.trace_ids.insert(ticket, rid);
            let ts = self.now_ns();
            self.tracer.emit(
                ts,
                TraceEvent::Submit {
                    req: rid,
                    prompt_tokens,
                    width: req.width.max(1),
                    prefix_hit_tokens: prefix_tokens,
                },
            );
        }
        Ok(ticket)
    }

    /// Probe the cold tier for pages extending a `hot_tokens`-long hot
    /// hit on `ids` and promote every consecutive hit back into the
    /// pool (verbatim — the cold block becomes the pool payload, so
    /// promotion never re-encodes; the restore path prices its decode
    /// into `kv.dequant_us`). Promoted pages are re-indexed under the
    /// hot tree so the caller's re-lookup picks them up. Returns the
    /// number of pages promoted.
    fn promote_cold_hits(&mut self, ids: &[u32], hot_tokens: usize) -> usize {
        let ps = self.geom.page_size;
        if ids.is_empty() {
            return 0;
        }
        // same one-page-short cap as RadixPrefixIndex::lookup
        let max_pages = (ids.len() - 1) / ps;
        let mut k = hot_tokens / ps;
        let mut adopted: BTreeMap<usize, PageId> = BTreeMap::new();
        while k < max_pages {
            let key = &ids[..(k + 1) * ps];
            let Some((page, data)) = self.cold.promote(key) else {
                break;
            };
            let id = self.cache.adopt_cold_page(page, data);
            adopted.insert(k, id);
            k += 1;
        }
        if adopted.is_empty() {
            return 0;
        }
        let n = adopted.len();
        // hand the promoted handles (one pool reference each) to the
        // index; pages below the hot-hit length are already present, so
        // the provider is called exactly for the promoted indices
        self.prefix_index.insert(&ids[..k * ps], |p| {
            adopted.remove(&p).expect("promoted page index")
        });
        n
    }

    /// Single typed submit entrypoint: one [`SubmitSpec`] carries the
    /// request, its client-visible trace id, and its optional SLO
    /// tier, replacing the `submit`/`submit_traced`/`assign_slo` call
    /// sequence (the older methods remain as thin wrappers for call
    /// sites that pin them). The serving `Backend` trait routes its
    /// sole `submit` through this.
    pub fn submit_spec(&mut self, session: &mut Session, spec: &SubmitSpec) -> Result<u64> {
        let ticket = self.submit_traced(session, &spec.request, spec.trace_id)?;
        if let Some(tier) = spec.slo {
            self.assign_slo(session, ticket, tier);
        }
        Ok(ticket)
    }

    /// Stamp a submitted ticket with its SLO tier: the scheduler
    /// records the tier on the request and its chains (EDF ordering,
    /// tier-aware preemption) with the absolute e2e deadline derived
    /// from the engine's trace clock, and the acceptance is counted.
    pub fn assign_slo(&mut self, session: &mut Session, ticket: u64, tier: SloTier) {
        let deadline_ns = self.now_ns() + tier.e2e_deadline_ns();
        session.sched.assign_slo(ticket, tier, deadline_ns);
        self.metrics.counter("serve.slo_accepted").inc();
        if self.tracer.enabled() {
            let req = self.trace_req(ticket);
            let ts = self.now_ns();
            self.tracer.emit(
                ts,
                TraceEvent::SloAssigned {
                    req,
                    tier: tier.name(),
                    ttft_deadline_ns: ts + tier.ttft_deadline_ns(),
                    e2e_deadline_ns: deadline_ns,
                },
            );
        }
    }

    /// Whether the session has no running or queued chains.
    pub fn is_idle(&self, session: &Session) -> bool {
        !session.sched.has_work()
    }

    /// Work-stealing handoff: remove up to `max_requests` *queued*
    /// requests from the session (only fresh ones — no chain
    /// installed, completed, or carrying resume state; see
    /// `Scheduler::drain_queued`) and return their tickets. Any
    /// prefix-cache page references the drained chains held while
    /// queued are released here — the stealing router re-submits the
    /// request on another replica, whose own prefix index is consulted
    /// from scratch. Installed chains are never migrated: their KV
    /// state is resident in this engine's lane regions and pool.
    pub fn drain_queued(&mut self, session: &mut Session, max_requests: usize) -> Vec<u64> {
        let drained = session.sched.drain_queued(max_requests);
        let mut tickets = Vec::with_capacity(drained.len());
        for (ticket, chains) in drained {
            for chain in chains {
                for id in chain.prefix_pages {
                    self.cache.release_page(id);
                }
            }
            // the stealing router re-submits elsewhere; this engine's
            // trace of the request ends here
            self.trace_ids.remove(&ticket);
            tickets.push(ticket);
        }
        tickets
    }

    /// Advance the session by one scheduler tick: admit (and possibly
    /// preempt), then issue one prefill chunk and/or one decode step
    /// across the active lanes. Returns every request whose last chain
    /// finished during the tick.
    pub fn tick(&mut self, session: &mut Session) -> Result<Vec<CompletedRequest>> {
        let sched = &mut session.sched;
        let stats = &mut session.stats;
        let mut completed = Vec::new();

        self.admit(sched, stats);
        let live_fraction = self.cache.live_fraction();
        if let Some((lane, ticket)) = sched.maybe_preempt_traced(live_fraction) {
            self.cache.recycle_lane(lane);
            stats.preemptions += 1;
            if self.tracer.enabled() {
                let req = self.trace_req(ticket);
                let ts = self.now_ns();
                self.tracer.emit(ts, TraceEvent::Preempt { req, lane });
            }
            self.admit(sched, stats);
        }
        if sched.active_lanes() == 0 {
            return Ok(completed);
        }

        stats.ticks += 1;
        self.tick_read_tokens = 0.0;
        let t0 = Instant::now();
        if self.prefill_step(sched, stats, &mut completed)? {
            stats.prefill_chunks += 1;
        }
        if self.decode_step(sched, stats, &mut completed)? {
            stats.decode_steps += 1;
        }
        stats.host_s += t0.elapsed().as_secs_f64();

        // flight recorder: this tick's cache event batches (eviction /
        // merge / COW / dequant), one event per touched lane
        if self.tracer.enabled() {
            let ts = self.now_ns();
            for (lane, ev) in self.cache.drain_tick_events() {
                if ev.cow_published > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::CowPublish {
                            lane,
                            pages: ev.cow_published,
                        },
                    );
                }
                if ev.dequant_pages > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::Dequant {
                            lane,
                            pages: ev.dequant_pages,
                        },
                    );
                }
                if ev.evictions + ev.merges > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::EvictBatch {
                            lane,
                            evictions: ev.evictions,
                            merges: ev.merges,
                            lh_touched: ev.lh_touched,
                        },
                    );
                }
            }
        }
        // per-tick memory-read accounting: token units priced into
        // full-model bytes under the store's dtype (paper x-axis)
        if self.tick_read_tokens > 0.0 {
            self.metrics.counter("kv.read_tokens").add(self.tick_read_tokens);
            self.metrics
                .counter("kv.read_bytes")
                .add(self.tick_read_tokens * self.kv_bytes_per_token());
        }

        let live_fraction = self.cache.live_fraction();
        let max_lane_fraction = (0..self.cfg.batch)
            .map(|lane| self.cache.lane_live_fraction(lane))
            .fold(0.0f64, f64::max);
        self.metrics
            .gauge("engine.active_lanes")
            .set(sched.active_lanes() as f64);
        self.metrics
            .gauge("engine.queue_depth")
            .set(sched.queue_depth() as f64);
        self.metrics.gauge("kv.live_fraction").set(live_fraction);
        self.metrics
            .gauge("kv.max_lane_live_fraction")
            .set(max_lane_fraction);
        self.metrics
            .gauge("kv.pool_pages")
            .set(self.cache.pool_pages() as f64);
        // cumulative COW snapshots; the store is the source of truth
        self.metrics
            .gauge("kv.cow_published_pages")
            .set(self.cache.cow_published() as f64);
        // quantized-payload accounting: nominal K+V bytes per cached
        // token per (layer, head) pair, actual pool payload bytes, the
        // cumulative dequant-on-upload cost, and the snapshot-buffer
        // acquisition cost (arena reuse or fresh alloc) kept separate
        // so codec time is never conflated with allocator churn
        self.metrics
            .gauge("kv.bytes_per_token")
            .set(self.cache.payload_bytes_per_token());
        self.metrics
            .gauge("kv.pool_payload_bytes")
            .set(self.cache.pool_payload_bytes() as f64);
        self.metrics
            .gauge("kv.dequant_us")
            .set(self.cache.dequant_us());
        self.metrics
            .gauge("kv.alloc_us")
            .set(self.cache.alloc_us());
        // tiered prefix-cache accounting: actual pool payload bytes
        // pinned by the hot index (promoted cold pages cost their
        // compressed size), plus the cold tier's RAM/disk footprint
        // and cumulative promote-side time
        let cache = &self.cache;
        let mut retained_bytes = 0usize;
        self.prefix_index
            .for_each_page(|id| retained_bytes += cache.page_payload_bytes(id));
        self.metrics
            .gauge("kv.prefix_retained_bytes")
            .set(retained_bytes as f64);
        self.metrics
            .gauge("kv.cold_tier_bytes")
            .set(self.cold.resident_bytes() as f64);
        self.metrics
            .gauge("kv.spilled_bytes")
            .set(self.cold.spilled_bytes() as f64);
        self.metrics
            .gauge("kv.cold_promote_us")
            .set(self.cold.promote_us() as f64);
        // budget-plan summaries across active planned lanes: aggregate
        // planned tokens, the per-head budget spread, and plan-aware
        // overflow (tokens above any head's budget — 0 under correct
        // head-granular enforcement)
        let (l, h) = (self.geom.layers, self.geom.kv_heads);
        let mut plan_lanes = 0usize;
        let mut plan_tokens = 0usize;
        let mut plan_min = usize::MAX;
        let mut plan_max = 0usize;
        let mut plan_overflow = 0usize;
        for lane in 0..self.cfg.batch {
            let Some(a) = sched.lane(lane) else { continue };
            let Some(plan) = a.policy.plan() else { continue };
            plan_lanes += 1;
            plan_tokens += plan.total(l, h);
            plan_min = plan_min.min(plan.min_budget());
            plan_max = plan_max.max(plan.max_budget());
            // prefill is dense by design (budgets are enforced from
            // post_prefill onward), so overflow is only meaningful on
            // decoding lanes — a mid-prefill lane legitimately holds
            // more than its budget. Quest's plan is a *read* budget
            // (nothing is ever evicted), so residency overflow does
            // not apply to it either.
            if matches!(a.phase, Phase::Decode) && a.policy.kind() != PolicyKind::Quest {
                plan_overflow += self.cache.plan_overflow(lane, plan);
            }
        }
        // always written, so the gauges drop to zero once the last
        // planned lane drains instead of going stale
        self.metrics.gauge("kv.plan_lanes").set(plan_lanes as f64);
        self.metrics.gauge("kv.plan_tokens").set(plan_tokens as f64);
        self.metrics
            .gauge("kv.plan_min_lh")
            .set(if plan_lanes > 0 { plan_min as f64 } else { 0.0 });
        self.metrics.gauge("kv.plan_max_lh").set(plan_max as f64);
        self.metrics
            .gauge("kv.plan_overflow_tokens")
            .set(plan_overflow as f64);
        let bpt = self.kv_bytes_per_token();
        for c in &completed {
            let t = &c.timing;
            self.metrics.histogram("serve.queue_ms").record(t.queue_ms);
            self.metrics.histogram("serve.ttft_ms").record(t.ttft_ms);
            self.metrics.histogram("serve.e2e_ms").record(t.e2e_ms);
            self.metrics
                .histogram("serve.req_tokens_per_s")
                .record(t.tokens_per_s());
            self.metrics.counter("serve.requests").inc();
            self.metrics
                .counter("serve.gen_tokens")
                .add(t.gen_tokens as f64);
            if let Some(tier) = c.slo {
                let ttft_budget_ms = tier.ttft_deadline_ns() as f64 / 1e6;
                let e2e_budget_ms = tier.e2e_deadline_ns() as f64 / 1e6;
                if t.ttft_ms > ttft_budget_ms {
                    self.metrics.counter("serve.slo_ttft_miss").inc();
                }
                if t.e2e_ms > e2e_budget_ms {
                    self.metrics.counter("serve.slo_deadline_miss").inc();
                } else {
                    self.metrics
                        .counter("serve.slo_goodput_tokens")
                        .add(t.gen_tokens as f64);
                }
            }
            let reads = c.result.total_reads();
            self.metrics.histogram("serve.kv_read_tokens").record(reads);
            if self.tracer.enabled() {
                let req = self.trace_req(c.ticket);
                let ts = self.now_ns();
                self.tracer.emit(
                    ts,
                    TraceEvent::Finish {
                        req,
                        gen_tokens: t.gen_tokens,
                        read_tokens: reads,
                        read_bytes: reads * bpt,
                    },
                );
            }
            self.trace_ids.remove(&c.ticket);
        }
        Ok(completed)
    }

    /// Fill idle lanes from the admission queue. A chain carrying a
    /// prefix-cache hit has the retained pages mapped into its lane
    /// (consuming the references it held while queued) and starts
    /// prefill at the divergence point.
    fn admit(&mut self, sched: &mut Scheduler, stats: &mut EngineStats) {
        while let Some(lane) = sched.idle_lane() {
            let Some(mut p) = sched.next_admission() else { break };
            self.cache.reset_lane(lane);
            let prefix_pages = std::mem::take(&mut p.prefix_pages);
            let prefix_tokens = p.prefix_tokens;
            let ticket = p.ticket;
            let policy = self.build_chain_policy(p.max_len);
            let mut chain = ChainState::new(p, policy, self.cfg.top_k);
            let restored_pages = prefix_pages.len();
            if !prefix_pages.is_empty() {
                self.cache.map_prefix_pages(lane, &prefix_pages);
                chain.phase = Phase::Prefill {
                    offset: prefix_tokens,
                };
                chain.stats.prefix_hit_tokens = prefix_tokens;
                stats.prefix_hit_tokens += prefix_tokens as u64;
            }
            sched.install(lane, chain);
            if self.tracer.enabled() {
                let req = self.trace_req(ticket);
                let ts = self.now_ns();
                self.tracer.emit(ts, TraceEvent::Admit { req, lane });
                if restored_pages > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::PrefixRestore {
                            req,
                            lane,
                            pages: restored_pages,
                            tokens: prefix_tokens,
                        },
                    );
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Static-batch API (unchanged signature)
    // ------------------------------------------------------------------

    /// Run a batch of requests to completion (continuous batching).
    pub fn run(&mut self, requests: &[GenRequest]) -> Result<(Vec<GenResult>, EngineStats)> {
        let mut session = self.begin_session();
        let mut tickets = Vec::with_capacity(requests.len());
        for req in requests {
            tickets.push(self.submit(&mut session, req)?);
        }
        let mut done: BTreeMap<u64, GenResult> = BTreeMap::new();
        while !self.is_idle(&session) {
            for c in self.tick(&mut session)? {
                done.insert(c.ticket, c.result);
            }
        }
        let out = tickets
            .iter()
            .map(|t| done.remove(t).expect("request completed"))
            .collect();
        Ok((out, session.stats.clone()))
    }

    /// Convenience: run a single request.
    pub fn generate(&mut self, req: GenRequest) -> Result<GenResult> {
        let (mut out, _) = self.run(std::slice::from_ref(&req))?;
        Ok(out.remove(0))
    }

    /// Open an engine from an artifacts path with defaults.
    pub fn open(artifacts: &Path) -> Result<Self> {
        Engine::new(EngineConfig {
            artifacts: artifacts.to_path_buf(),
            ..Default::default()
        })
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    fn prefill_step(
        &mut self,
        sched: &mut Scheduler,
        stats: &mut EngineStats,
        completed: &mut Vec<CompletedRequest>,
    ) -> Result<bool> {
        let b = self.cfg.batch;
        let c = self.prefill_exec.meta.chunk;
        let (l, h, hd) = (self.geom.layers, self.geom.kv_heads, self.geom.head_dim);

        let pb = batch::assemble_prefill(sched.lanes(), b, c, PAD_ID as i32);
        if pb.is_empty() {
            return Ok(false);
        }
        self.metrics
            .counter("engine.prefill_tokens")
            .add(pb.total_tokens() as f64);
        // shared pages mapped at admission (prefix hits) must be
        // resident in their lanes' regions before the executor reads
        self.cache.materialize_pending();

        let t0 = Instant::now();
        let out = self.prefill_exec.prefill(
            self.weights.literals(),
            self.cache.k_slice(),
            self.cache.v_slice(),
            self.cache.mask_slice(),
            &pb.tokens,
            &pb.positions,
            &pb.valid,
            &self.geom,
        )?;
        stats.executor_s += t0.elapsed().as_secs_f64();

        // write chunk outputs per prefilling lane
        let honor_alpha = self.dms_variant
            && matches!(
                self.cfg.policy,
                PolicyKind::Dms | PolicyKind::DmsImmediate
            );
        for lane in 0..b {
            let n = pb.chunk_lens[lane];
            if n == 0 {
                continue;
            }
            let offset = match sched.lane(lane).map(|a| a.phase) {
                Some(Phase::Prefill { offset }) => offset,
                _ => continue,
            };
            let cache_live_before = self.cache.live_tokens(lane);

            // per-position α view for the lane's budget-plan stats
            // (the retrofit exports α chunk-wise during prefill);
            // only the adaptive allocator consumes it
            let track_alpha =
                honor_alpha && self.cfg.allocator == AllocatorKind::Adaptive;
            for j in 0..n {
                let pos = offset + j;
                let mut overflow = false;
                let mut step_alpha = if track_alpha {
                    vec![0f32; l * h]
                } else {
                    Vec::new()
                };
                for li in 0..l {
                    for hi in 0..h {
                        let base = ((((li * b) + lane) * h + hi) * c + j) * hd;
                        let kk = &out.k_new[base..base + hd];
                        let vv = &out.v_new[base..base + hd];
                        match self.cache.alloc_slot(lane, li, hi) {
                            Some(s) => {
                                self.cache.write(lane, li, hi, s, pos, kk, vv);
                                if honor_alpha {
                                    let ai = (((li * b) + lane) * h + hi) * c + j;
                                    if track_alpha {
                                        step_alpha[li * h + hi] = out.alpha[ai];
                                    }
                                    if out.alpha[ai] > 0.5 {
                                        if self.immediate {
                                            if pos >= self.window {
                                                let target = pos - self.window;
                                                if let Some((es, _)) = self
                                                    .cache
                                                    .live_slots(lane, li, hi)
                                                    .into_iter()
                                                    .find(|&(_, p)| p == target)
                                                {
                                                    self.cache.evict(lane, li, hi, es);
                                                }
                                            }
                                        } else {
                                            self.cache.schedule_eviction(
                                                lane,
                                                li,
                                                hi,
                                                s,
                                                pos + self.window,
                                            );
                                        }
                                    }
                                }
                            }
                            None => overflow = true,
                        }
                    }
                }
                if track_alpha {
                    sched
                        .lane_mut(lane)
                        .unwrap()
                        .attn_stats
                        .observe_alpha(l, h, &step_alpha);
                }
                // reads: existing cache + intra-chunk causal visibility
                let step_reads = cache_live_before + (j + 1) as f64;
                sched.lane_mut(lane).unwrap().stats.prefill_reads += step_reads;
                self.tick_read_tokens += step_reads;
                if overflow {
                    // prompt doesn't fit (vanilla long-context): finish now
                    let chain = sched.take(lane).unwrap();
                    if let Some(done) =
                        self.finish_chain(chain, lane, FinishReason::Overflow, sched)
                    {
                        completed.push(done);
                    }
                    break;
                }
            }
            if sched.lane(lane).is_none() {
                continue; // overflowed above
            }
            self.cache.apply_due_evictions(lane, offset + n);
            let peak = self.cache.live_tokens(lane);
            let a = sched.lane_mut(lane).unwrap();
            if peak > a.stats.peak_tokens {
                a.stats.peak_tokens = peak;
            }

            let new_offset = offset + n;
            if new_offset == a.prefill_ids.len() {
                // prefill complete: trim to budget, sample first token
                a.policy.post_prefill(&mut self.cache, lane, new_offset);
                let v = self.runtime.manifest.config.vocab;
                let last = n - 1;
                let logits = &out.logits[(lane * c + last) * v..(lane * c + last + 1) * v];
                // a resumed chain already sampled its next token before
                // the preemption — continue with it, untouched RNG.
                let resumed = a.resume_token.is_some();
                let tok = match a.resume_token.take() {
                    Some(t) => t,
                    None => a.sampler.sample(logits),
                };
                a.cur_token = tok;
                a.pos = new_offset;
                a.phase = Phase::Decode;
                let ticket = a.ticket;
                if sched.note_first_token(ticket) && self.tracer.enabled() {
                    let req = self.trace_req(ticket);
                    let ts = self.now_ns();
                    self.tracer.emit(ts, TraceEvent::FirstToken { req });
                }
                // fork siblings into idle lanes (prefix sharing) — but
                // never off a resumed chain: its re-prefilled cache
                // holds generated tokens, not just the prompt, so
                // stranded siblings self-prefill via promotion instead.
                if !resumed {
                    self.fork_siblings(sched, lane, ticket, tok, new_offset, stats);
                }
            } else {
                a.phase = Phase::Prefill { offset: new_offset };
            }
        }
        Ok(true)
    }

    fn fork_siblings(
        &mut self,
        sched: &mut Scheduler,
        src_lane: usize,
        ticket: u64,
        leader_token: u32,
        leader_pos: usize,
        stats: &mut EngineStats,
    ) {
        // src_lane is occupied, so idle_lane() can never return it.
        // Fork siblings inherit the leader's current budget plan: the
        // shared prefill was shaped under it, and diverging plans at
        // fork time would make sibling streams depend on lane timing.
        let leader_plan = sched
            .lane(src_lane)
            .and_then(|c| c.policy.plan().cloned());
        loop {
            let Some(dst) = sched.idle_lane() else { break };
            let Some(mut p) = sched.take_fork_sibling(ticket) else { break };
            // the sibling shares the leader's lane instead of using its
            // queued prefix hit: drop the references it held
            for id in std::mem::take(&mut p.prefix_pages) {
                self.cache.release_page(id);
            }
            // refcount-bump fork: siblings share the leader's prefill
            // pages copy-on-write; payload copies are page-granular and
            // deferred to the next materialize_pending
            let shared = self.cache.fork_lane_cow(src_lane, dst);
            self.metrics
                .counter("kv.fork_shared_pages")
                .add(shared as f64);
            let mut policy = self.build_chain_policy(p.max_len);
            if let Some(plan) = leader_plan.clone() {
                policy.install_plan(plan);
            }
            sched.install(
                dst,
                ChainState::forked(p, policy, self.cfg.top_k, leader_token, leader_pos),
            );
            stats.forks += 1;
        }
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    fn decode_step(
        &mut self,
        sched: &mut Scheduler,
        stats: &mut EngineStats,
        completed: &mut Vec<CompletedRequest>,
    ) -> Result<bool> {
        let b = self.cfg.batch;
        let (l, h, hd) = (self.geom.layers, self.geom.kv_heads, self.geom.head_dim);
        let lh = l * h;
        let v = self.runtime.manifest.config.vocab;

        // execute due delayed evictions before packing the step
        for lane in 0..b {
            if let Some(a) = sched.lane(lane) {
                if matches!(a.phase, Phase::Decode) {
                    let pos = a.pos;
                    self.cache.apply_due_evictions(lane, pos);
                }
            }
        }
        let db = batch::assemble_decode(sched.lanes(), b, PAD_ID as i32);
        if db.is_empty() {
            return Ok(false);
        }
        // COW-forked siblings installed this tick carry unmaterialized
        // shared pages; fill their regions before the executor reads
        self.cache.materialize_pending();

        let quest = self.cfg.policy == PolicyKind::Quest;
        let quest_k = {
            let ml = db
                .lanes
                .iter()
                .filter_map(|&i| sched.lane(i))
                .map(|a| a.max_len)
                .max()
                .unwrap_or(160);
            self.quest_k(ml)
        };

        // reads observed by this step (before the new token is written)
        let mut live_before = vec![0f64; b];
        let mut pages_before = vec![0usize; b];
        for &lane in &db.lanes {
            live_before[lane] = self.cache.live_tokens(lane);
            if quest {
                let mut pages = 0;
                for li in 0..l {
                    for hi in 0..h {
                        pages += self.cache.allocated_pages(lane, li, hi);
                    }
                }
                pages_before[lane] = pages;
            }
        }

        let t0 = Instant::now();
        let out = match &self.param_bufs {
            Some(pb) => self.decode_exec.decode_buffered(
                pb,
                self.cache.k_slice(),
                self.cache.v_slice(),
                &db.tokens,
                &db.positions,
                self.cache.mask_slice(),
                self.cache.pmin_slice(),
                self.cache.pmax_slice(),
                quest_k,
                &self.geom,
            )?,
            None => self.decode_exec.decode(
                self.weights.literals(),
                self.cache.k_slice(),
                self.cache.v_slice(),
                &db.tokens,
                &db.positions,
                self.cache.mask_slice(),
                self.cache.pmin_slice(),
                self.cache.pmax_slice(),
                quest_k,
                &self.geom,
            )?,
        };
        stats.executor_s += t0.elapsed().as_secs_f64();

        // per-lane host work (view gather, policy scoring, sampling) —
        // parallel across lanes, results in ascending lane order.
        let steps = batch::decode_host_work(
            sched.lanes_mut(),
            &out,
            self.geom,
            b,
            v,
            quest,
            self.cfg.lane_threads,
            self.cfg.allocator == AllocatorKind::Adaptive,
        );

        let mut written: Vec<Option<usize>> = vec![None; lh];
        for step in &steps {
            let lane = step.lane;
            let a = sched.lane_mut(lane).unwrap();

            // ---- reads accounting (§5.1) ----
            let step_reads = if quest {
                let page_reads =
                    step.quest_sel_pages as f64 * self.geom.page_size as f64 / lh as f64;
                let meta_reads = pages_before[lane] as f64
                    * crate::compress::quest::QuestPolicy::META_TOKENS_PER_PAGE
                    / lh as f64;
                page_reads.min(live_before[lane]) + meta_reads + 1.0
            } else {
                live_before[lane] + 1.0
            };
            a.stats.decode_reads += step_reads;
            self.tick_read_tokens += step_reads;

            // ---- write the new token ----
            let pos = a.pos;
            let mut overflow = false;
            for li in 0..l {
                for hi in 0..h {
                    let i = li * h + hi;
                    let base = ((li * b) + lane) * h + hi;
                    let kk = &out.k_new[base * hd..(base + 1) * hd];
                    let vv = &out.v_new[base * hd..(base + 1) * hd];
                    written[i] = None;
                    match step.actions[i] {
                        WriteAction::Merge => {
                            if !self.cache.merge_into_last(lane, li, hi, kk, vv) {
                                // nothing to merge into: fall back to append
                                match self.cache.alloc_slot(lane, li, hi) {
                                    Some(slot) => {
                                        self.cache.write(lane, li, hi, slot, pos, kk, vv);
                                        written[i] = Some(slot);
                                    }
                                    None => overflow = true,
                                }
                            }
                        }
                        WriteAction::Append => match self.cache.alloc_slot(lane, li, hi) {
                            Some(slot) => {
                                self.cache.write(lane, li, hi, slot, pos, kk, vv);
                                written[i] = Some(slot);
                            }
                            None => overflow = true,
                        },
                    }
                }
            }

            let view = StepView {
                lane,
                pos,
                alpha: &step.alpha,
                attn: &step.attn,
                attn_self: &step.attn_self,
                written: &written,
            };
            a.policy.post_write(&mut self.cache, &view);

            // ---- per-chain bookkeeping ----
            let evict_decisions =
                step.alpha.iter().filter(|&&x| x > 0.5).count() as u16;
            a.stats.evictions_per_pos.push(evict_decisions);
            let mut peak = self.cache.live_tokens(lane);
            if quest {
                let mut pages = 0;
                for li in 0..l {
                    for hi in 0..h {
                        pages += self.cache.allocated_pages(lane, li, hi);
                    }
                }
                peak += pages as f64
                    * crate::compress::quest::QuestPolicy::META_TOKENS_PER_PAGE
                    / lh as f64;
            }
            if peak > a.stats.peak_tokens {
                a.stats.peak_tokens = peak;
            }

            // ---- adaptive re-planning ----
            // every `replan_interval` generated tokens, reshape the
            // chain's budget plan from its accumulated attention
            // statistics. Heads whose budgets shrank are trimmed
            // immediately (recency-first via post_prefill, the same
            // mechanism as the App. F.1 post-prefill switch), so the
            // plan-overflow invariant holds within the same tick.
            // Signal-free allocators never re-plan.
            if self.cfg.allocator == AllocatorKind::Adaptive
                && a.policy.plan().is_some()
                && !a.gen_ids.is_empty()
                && a.gen_ids.len() % self.cfg.replan_interval == 0
            {
                let plan = self.allocator.plan(
                    self.geom.layers,
                    self.geom.kv_heads,
                    self.global_budget(a.max_len),
                    Some(&a.attn_stats),
                );
                a.policy.install_plan(plan);
                a.policy.post_prefill(&mut self.cache, lane, a.pos);
                self.metrics.counter("kv.plan_replans").inc();
            }

            // ---- advance & check termination ----
            let tok = step.next_token;
            a.gen_ids.push(a.cur_token);
            a.pos += 1;
            a.cur_token = tok;

            let finish = if overflow {
                Some(FinishReason::Overflow)
            } else if tok == EOS_ID || tok == self.newline_id {
                if tok == self.newline_id {
                    a.gen_ids.push(tok);
                }
                Some(FinishReason::Stop)
            } else if a.pos + 1 >= a.max_len {
                a.gen_ids.push(tok);
                Some(FinishReason::Length)
            } else {
                None
            };

            if let Some(reason) = finish {
                let chain = sched.take(lane).unwrap();
                if let Some(done) = self.finish_chain(chain, lane, reason, sched) {
                    completed.push(done);
                }
            }
        }
        Ok(true)
    }

    /// Retire a chain: record its final stats, decode its text, recycle
    /// the lane's cache slots back to the allocator, and report the
    /// request if this was its last chain.
    fn finish_chain(
        &mut self,
        mut a: ChainState,
        lane: usize,
        finish: FinishReason,
        sched: &mut Scheduler,
    ) -> Option<CompletedRequest> {
        let (l, h) = (self.geom.layers, self.geom.kv_heads);
        let mut retained = Vec::with_capacity(l * h);
        for li in 0..l {
            for hi in 0..h {
                retained.push((self.cache.live_count(lane, li, hi), a.pos));
            }
        }
        a.stats.retained_per_lh = retained;
        a.stats.final_tokens = self.cache.live_tokens(lane);
        a.stats.gen_tokens = a.gen_ids.len();
        a.stats.wall_s += a.started.elapsed().as_secs_f64();
        // generated text excludes the prompt (gen_ids holds only
        // generated tokens)
        let text = self.tokenizer.decode(&a.gen_ids);
        // prefix retention: if the leading prompt pages survived every
        // compression decision untouched (identity slot layout, no
        // pending evictions, no merges), publish them into the pool and
        // index them under the prompt's token ids, then trim the index
        // back under its LRU budget.
        let mut indexed = false;
        if self.cfg.prefix_cache {
            let n = self.cache.clean_prefix_pages(lane, a.stats.prompt_tokens);
            if n > 0 {
                let ps = self.geom.page_size;
                let ids = &a.prefill_ids[..n * ps];
                let cache = &mut self.cache;
                self.prefix_index
                    .insert(ids, |p| cache.export_page(lane, p));
                indexed = true;
            }
        }
        let freed = self.cache.recycle_lane(lane);
        self.metrics.counter("kv.slots_recycled").add(freed as f64);
        // trim AFTER the lane released its shares: a trimmed page's
        // final reference is then the index's own, so demotion can
        // capture the payload instead of finding it still lane-mapped
        if indexed {
            if self.cold.enabled() {
                // demote-instead-of-free: pages the LRU trim would
                // drop are re-encoded once into the cold dtype and
                // kept under the cold-tier budget, keyed by their
                // covering token prefix. Pages still shared with
                // another lane stay alive there and just lose cold
                // coverage.
                let cache = &mut self.cache;
                let cold = &mut self.cold;
                self.prefix_index
                    .trim_with(self.cfg.prefix_cache_pages, |key, id| {
                        if let Some((page, data)) = cache.demote_page(id) {
                            cold.admit(key, page, data);
                        }
                    });
            } else {
                for id in self.prefix_index.trim(self.cfg.prefix_cache_pages) {
                    self.cache.release_page(id);
                }
            }
            self.metrics
                .gauge("kv.prefix_pages_retained")
                .set(self.prefix_index.pages_retained() as f64);
        }
        sched.complete(
            a.ticket,
            a.chain_idx,
            ChainResult {
                text,
                finish,
                stats: a.stats,
            },
        )
    }
}
