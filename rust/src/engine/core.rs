//! Engine core: lane scheduler, prefill/decode loop, metric accounting.

use std::collections::VecDeque;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::sampler::Sampler;
use super::sequence::{ChainResult, ChainStats, FinishReason, GenRequest, GenResult};
use crate::compress::{build_policy, Policy, PolicyKind, StepView, WriteAction};
use crate::config::EngineConfig;
use crate::kvcache::{CacheStore, Geometry};
use crate::metrics::Registry;
use crate::runtime::{Executor, ParamBuffers, Runtime, Weights};
use crate::tokenizer::{Tokenizer, BOS_ID, EOS_ID, PAD_ID};

/// Aggregate engine statistics for a `run` call.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    pub executor_s: f64,
    pub host_s: f64,
    pub forks: u64,
}

enum Phase {
    Prefill { offset: usize },
    Decode,
}

struct Active {
    req_idx: usize,
    chain_idx: usize,
    group: usize,
    prompt_ids: Rc<Vec<u32>>,
    max_len: usize,
    policy: Box<dyn Policy>,
    sampler: Sampler,
    phase: Phase,
    cur_token: u32,
    pos: usize,
    gen_ids: Vec<u32>,
    stats: ChainStats,
    started: Instant,
}

struct PendingChain {
    req_idx: usize,
    chain_idx: usize,
    group: usize,
    prompt_ids: Rc<Vec<u32>>,
    max_len: usize,
    temperature: f64,
    seed: u64,
    /// Group sibling that waits for a fork from the leader's prefill.
    wait_fork: bool,
}

/// The inference engine: one executor batch + policy + metrics.
pub struct Engine {
    pub runtime: Runtime,
    pub cfg: EngineConfig,
    pub tokenizer: Tokenizer,
    pub metrics: Registry,
    geom: Geometry,
    weights: Rc<Weights>,
    /// Device-resident parameters (buffered-exec fast path).
    param_bufs: Option<ParamBuffers>,
    decode_exec: Executor,
    prefill_exec: Executor,
    cache: CacheStore,
    /// Retrofit metadata of the loaded variant.
    window: usize,
    immediate: bool,
    dms_variant: bool,
    newline_id: u32,
}

impl Engine {
    /// Open artifacts, load the variant's weights, compile executables.
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let runtime = Runtime::open(&cfg.artifacts)?;
        let tokenizer = Tokenizer::new();
        tokenizer.check_manifest_vocab(&runtime.manifest.vocab)?;

        let vmeta = runtime
            .manifest
            .variants
            .get(&cfg.variant)
            .ok_or_else(|| anyhow!("variant '{}' missing from manifest", cfg.variant))?
            .clone();
        let dms_variant = vmeta.alpha_mode.starts_with("dms");
        let weights = runtime.load_weights(&cfg.variant)?;

        let dname = runtime.decode_exe_name(cfg.batch, cfg.slots, cfg.use_jnp_decode)?;
        let dmeta = runtime.manifest.executables[&dname].clone();
        let decode_exec = Executor::new(runtime.load_executable(&dname)?, dmeta);

        // prefill flavour follows the variant (DMS window/immediate) and
        // whether the engine policy exploits sparsity during prefill.
        let use_dms_prefill = dms_variant
            && matches!(cfg.policy, PolicyKind::Dms | PolicyKind::DmsImmediate);
        let pname = runtime.prefill_exe_name(
            cfg.batch,
            cfg.slots,
            vmeta.window,
            vmeta.immediate,
            use_dms_prefill,
        )?;
        let pmeta = runtime.manifest.executables[&pname].clone();
        let prefill_exec = Executor::new(runtime.load_executable(&pname)?, pmeta);

        let geom = runtime.manifest.cache_geometry(cfg.slots);
        let cache = CacheStore::new(geom, cfg.batch);
        let newline_id = tokenizer.newline_id();
        let param_bufs = if cfg.buffered_exec {
            Some(ParamBuffers::from_weights(&runtime.client, &weights)?)
        } else {
            None
        };
        Ok(Self {
            runtime,
            tokenizer,
            metrics: Registry::default(),
            geom,
            weights,
            param_bufs,
            decode_exec,
            prefill_exec,
            cache,
            window: vmeta.window,
            immediate: vmeta.immediate,
            dms_variant,
            cfg,
            newline_id,
        })
    }

    pub fn geometry(&self) -> Geometry {
        self.geom
    }

    /// Switch the compression policy (+ CR) without recompiling the
    /// decode executable; the prefill flavour is re-selected (cached).
    pub fn set_policy(&mut self, kind: PolicyKind, cr: f64) -> Result<()> {
        self.cfg.policy = kind;
        self.cfg.cr = cr;
        self.reload_prefill()
    }

    /// Switch the model variant (weights + retrofit metadata).
    pub fn set_variant(&mut self, variant: &str) -> Result<()> {
        let vmeta = self
            .runtime
            .manifest
            .variants
            .get(variant)
            .ok_or_else(|| anyhow!("variant '{variant}' missing from manifest"))?
            .clone();
        self.cfg.variant = variant.to_string();
        self.weights = self.runtime.load_weights(variant)?;
        self.param_bufs = if self.cfg.buffered_exec {
            Some(ParamBuffers::from_weights(&self.runtime.client, &self.weights)?)
        } else {
            None
        };
        self.window = vmeta.window;
        self.immediate = vmeta.immediate;
        self.dms_variant = vmeta.alpha_mode.starts_with("dms");
        self.reload_prefill()
    }

    fn reload_prefill(&mut self) -> Result<()> {
        let use_dms_prefill = self.dms_variant
            && matches!(
                self.cfg.policy,
                PolicyKind::Dms | PolicyKind::DmsImmediate
            );
        let pname = self.runtime.prefill_exe_name(
            self.cfg.batch,
            self.cfg.slots,
            self.window,
            self.immediate,
            use_dms_prefill,
        )?;
        let pmeta = self.runtime.manifest.executables[&pname].clone();
        self.prefill_exec = Executor::new(self.runtime.load_executable(&pname)?, pmeta);
        Ok(())
    }

    /// Metrics snapshot for the server's stats endpoint.
    pub fn metrics_report(&self) -> String {
        self.metrics.report()
    }

    /// Quest page budget for a run configuration (scalar for the whole
    /// batch — all chains in a run share max_len and CR).
    fn quest_k(&self, max_len: usize) -> i32 {
        if self.cfg.policy == PolicyKind::Quest {
            let budget = (max_len as f64 / self.cfg.cr).ceil() as usize;
            (budget.div_ceil(self.geom.page_size)).max(1) as i32
        } else {
            self.geom.pages() as i32
        }
    }

    fn build_chain_policy(&self, max_len: usize) -> Box<dyn Policy> {
        build_policy(
            self.cfg.policy,
            self.cfg.cr,
            max_len,
            self.window,
            self.geom.page_size,
        )
    }

    /// Run a batch of requests to completion (continuous batching).
    pub fn run(&mut self, requests: &[GenRequest]) -> Result<(Vec<GenResult>, EngineStats)> {
        let b = self.cfg.batch;
        let mut stats = EngineStats::default();
        let mut pending: VecDeque<PendingChain> = VecDeque::new();
        let mut results: Vec<Vec<Option<ChainResult>>> = Vec::new();

        let mut group_counter = 0usize;
        for (ri, req) in requests.iter().enumerate() {
            let ids: Vec<u32> = {
                let mut v = vec![BOS_ID];
                v.extend(self.tokenizer.encode(&req.prompt)?);
                v
            };
            if ids.len() + 2 > req.max_len {
                bail!(
                    "prompt ({} tokens) does not fit max_len {}",
                    ids.len(),
                    req.max_len
                );
            }
            if req.max_len > self.geom.slots {
                bail!("max_len {} exceeds slot capacity {}", req.max_len, self.geom.slots);
            }
            let ids = Rc::new(ids);
            results.push(vec![None; req.width]);
            let group = group_counter;
            group_counter += 1;
            for w in 0..req.width {
                pending.push_back(PendingChain {
                    req_idx: ri,
                    chain_idx: w,
                    group,
                    prompt_ids: ids.clone(),
                    max_len: req.max_len,
                    temperature: req.temperature,
                    seed: req.seed.wrapping_add(w as u64),
                    wait_fork: w > 0,
                });
            }
        }

        let mut lanes: Vec<Option<Active>> = (0..b).map(|_| None).collect();
        let run_quest_k = self.quest_k(requests.first().map(|r| r.max_len).unwrap_or(160));

        loop {
            // ---- fill idle lanes ----
            self.fill_lanes(&mut lanes, &mut pending, &mut stats);
            if lanes.iter().all(Option::is_none) {
                break;
            }
            let any_prefill = lanes
                .iter()
                .flatten()
                .any(|a| matches!(a.phase, Phase::Prefill { .. }));
            let t0 = Instant::now();
            if any_prefill {
                self.prefill_step(&mut lanes, &mut pending, &mut results, &mut stats)?;
                stats.prefill_chunks += 1;
            } else {
                self.decode_step(&mut lanes, &mut results, &mut stats, run_quest_k)?;
                stats.decode_steps += 1;
            }
            stats.host_s += t0.elapsed().as_secs_f64();
        }

        let out = results
            .into_iter()
            .map(|chains| GenResult {
                chains: chains.into_iter().map(|c| c.unwrap()).collect(),
            })
            .collect();
        Ok((out, stats))
    }

    fn fill_lanes(
        &mut self,
        lanes: &mut [Option<Active>],
        pending: &mut VecDeque<PendingChain>,
        _stats: &mut EngineStats,
    ) {
        for lane in 0..lanes.len() {
            if lanes[lane].is_some() {
                continue;
            }
            // prefer chains that are not waiting for a fork; a waiting
            // sibling whose leader is gone is promoted to self-prefill.
            let idx = pending.iter().position(|p| !p.wait_fork).or_else(|| {
                pending.iter().position(|p| {
                    // leader no longer active or pending → self-prefill
                    let leader_active = lanes.iter().flatten().any(|a| {
                        a.group == p.group && matches!(a.phase, Phase::Prefill { .. })
                    });
                    let leader_pending = pending
                        .iter()
                        .any(|q| q.group == p.group && !q.wait_fork);
                    !leader_active && !leader_pending
                })
            });
            let Some(idx) = idx else { continue };
            let p = pending.remove(idx).unwrap();
            self.cache.reset_lane(lane);
            let policy = self.build_chain_policy(p.max_len);
            lanes[lane] = Some(Active {
                req_idx: p.req_idx,
                chain_idx: p.chain_idx,
                group: p.group,
                prompt_ids: p.prompt_ids.clone(),
                max_len: p.max_len,
                policy,
                sampler: Sampler::new(p.temperature, self.cfg.top_k, p.seed),
                phase: Phase::Prefill { offset: 0 },
                cur_token: PAD_ID,
                pos: 0,
                gen_ids: Vec::new(),
                stats: ChainStats {
                    prompt_tokens: p.prompt_ids.len(),
                    ..Default::default()
                },
                started: Instant::now(),
            });
        }
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    fn prefill_step(
        &mut self,
        lanes: &mut [Option<Active>],
        pending: &mut VecDeque<PendingChain>,
        results: &mut [Vec<Option<ChainResult>>],
        stats: &mut EngineStats,
    ) -> Result<()> {
        let b = self.cfg.batch;
        let c = self.prefill_exec.meta.chunk;
        let (l, h, hd) = (self.geom.layers, self.geom.kv_heads, self.geom.head_dim);

        let mut tokens = vec![PAD_ID as i32; b * c];
        let mut positions = vec![0i32; b * c];
        let mut valid = vec![0f32; b * c];
        let mut chunk_lens = vec![0usize; b];

        for (lane, slot) in lanes.iter().enumerate() {
            let Some(a) = slot else { continue };
            let Phase::Prefill { offset } = a.phase else { continue };
            let n = (a.prompt_ids.len() - offset).min(c);
            chunk_lens[lane] = n;
            for j in 0..n {
                tokens[lane * c + j] = a.prompt_ids[offset + j] as i32;
                positions[lane * c + j] = (offset + j) as i32;
                valid[lane * c + j] = 1.0;
            }
        }

        let t0 = Instant::now();
        let out = self.prefill_exec.prefill(
            self.weights.literals(),
            self.cache.k_slice(),
            self.cache.v_slice(),
            self.cache.mask_slice(),
            &tokens,
            &positions,
            &valid,
            &self.geom,
        )?;
        stats.executor_s += t0.elapsed().as_secs_f64();

        // write chunk outputs per prefilling lane
        for lane in 0..b {
            let n = chunk_lens[lane];
            if n == 0 {
                continue;
            }
            let Some(a) = lanes[lane].as_mut() else { continue };
            let Phase::Prefill { offset } = a.phase else { continue };
            let cache_live_before = self.cache.live_tokens(lane);
            let honor_alpha = self.dms_variant
                && matches!(
                    self.cfg.policy,
                    PolicyKind::Dms | PolicyKind::DmsImmediate
                );

            for j in 0..n {
                let pos = offset + j;
                let mut overflow = false;
                for li in 0..l {
                    for hi in 0..h {
                        let base =
                            ((((li * b) + lane) * h + hi) * c + j) * hd;
                        let kk = &out.k_new[base..base + hd];
                        let vv = &out.v_new[base..base + hd];
                        match self.cache.alloc_slot(lane, li, hi) {
                            Some(s) => {
                                self.cache.write(lane, li, hi, s, pos, kk, vv);
                                if honor_alpha {
                                    let ai = (((li * b) + lane) * h + hi) * c + j;
                                    if out.alpha[ai] > 0.5 {
                                        if self.immediate {
                                            if pos >= self.window {
                                                let target = pos - self.window;
                                                if let Some((es, _)) = self
                                                    .cache
                                                    .live_slots(lane, li, hi)
                                                    .into_iter()
                                                    .find(|&(_, p)| p == target)
                                                {
                                                    self.cache.evict(lane, li, hi, es);
                                                }
                                            }
                                        } else {
                                            self.cache.schedule_eviction(
                                                lane,
                                                li,
                                                hi,
                                                s,
                                                pos + self.window,
                                            );
                                        }
                                    }
                                }
                            }
                            None => overflow = true,
                        }
                    }
                }
                // reads: existing cache + intra-chunk causal visibility
                a.stats.prefill_reads += cache_live_before + (j + 1) as f64;
                if overflow {
                    // prompt doesn't fit (vanilla long-context): finish now
                    let a = lanes[lane].take().unwrap();
                    self.finish_chain(a, lane, FinishReason::Overflow, results);
                    break;
                }
            }
            if lanes[lane].is_none() {
                continue; // overflowed above
            }
            let a = lanes[lane].as_mut().unwrap();
            self.cache.apply_due_evictions(lane, offset + n);
            let peak = self.lane_peak_tokens(lane);
            if peak > a.stats.peak_tokens {
                a.stats.peak_tokens = peak;
            }

            let new_offset = offset + n;
            if new_offset == a.prompt_ids.len() {
                // prefill complete: trim to budget, sample first token
                a.policy.post_prefill(&mut self.cache, lane, new_offset);
                let v = self.runtime.manifest.config.vocab;
                let last = n - 1;
                let logits = &out.logits[(lane * c + last) * v..(lane * c + last + 1) * v];
                let tok = a.sampler.sample(logits);
                a.cur_token = tok;
                a.pos = new_offset;
                a.phase = Phase::Decode;
                let group = a.group;
                // fork siblings into idle lanes (prefix sharing)
                self.fork_siblings(lanes, pending, lane, group, stats);
            } else {
                a.phase = Phase::Prefill { offset: new_offset };
            }
        }
        Ok(())
    }

    fn fork_siblings(
        &mut self,
        lanes: &mut [Option<Active>],
        pending: &mut VecDeque<PendingChain>,
        src_lane: usize,
        group: usize,
        stats: &mut EngineStats,
    ) {
        loop {
            let Some(dst) = (0..lanes.len()).find(|&i| i != src_lane && lanes[i].is_none())
            else {
                break;
            };
            let Some(pi) = pending.iter().position(|p| p.group == group && p.wait_fork)
            else {
                break;
            };
            let p = pending.remove(pi).unwrap();
            self.cache.fork_lane(src_lane, dst);
            let src = lanes[src_lane].as_ref().unwrap();
            let mut sampler = Sampler::new(p.temperature, self.cfg.top_k, p.seed);
            // the sibling samples its own first token from the same
            // prefill logits — approximated by re-sampling from the
            // leader's: we reuse the leader's first token distribution
            // by sampling with the sibling's RNG on the next decode
            // step. Simplest faithful approach: sibling starts from the
            // leader's first sampled token only if greedy; otherwise we
            // resample on first decode by feeding the same position.
            let cur = if p.temperature <= 0.0 {
                src.cur_token
            } else {
                // diversity: sample from leader's logits is not stored;
                // use leader token but rely on temperature at later
                // steps (first tokens of reasoning traces are nearly
                // deterministic in this task family).
                src.cur_token
            };
            let stats_c = ChainStats {
                prompt_tokens: src.prompt_ids.len(),
                forked_prefill: true,
                ..Default::default()
            };
            sampler.sample(&[0.0]); // decorrelate RNG streams
            lanes[dst] = Some(Active {
                req_idx: p.req_idx,
                chain_idx: p.chain_idx,
                group,
                prompt_ids: p.prompt_ids.clone(),
                max_len: p.max_len,
                policy: self.build_chain_policy(p.max_len),
                sampler,
                phase: Phase::Decode,
                cur_token: cur,
                pos: src.pos,
                gen_ids: Vec::new(),
                stats: stats_c,
                started: Instant::now(),
            });
            stats.forks += 1;
        }
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    fn decode_step(
        &mut self,
        lanes: &mut [Option<Active>],
        results: &mut [Vec<Option<ChainResult>>],
        stats: &mut EngineStats,
        quest_k: i32,
    ) -> Result<()> {
        let b = self.cfg.batch;
        let (l, h, s, hd) = (
            self.geom.layers,
            self.geom.kv_heads,
            self.geom.slots,
            self.geom.head_dim,
        );
        let lh = l * h;
        let v = self.runtime.manifest.config.vocab;

        let mut tokens = vec![PAD_ID as i32; b];
        let mut positions = vec![0i32; b];
        for (lane, slot) in lanes.iter().enumerate() {
            if let Some(a) = slot {
                if matches!(a.phase, Phase::Decode) {
                    tokens[lane] = a.cur_token as i32;
                    positions[lane] = a.pos as i32;
                    self.cache.apply_due_evictions(lane, a.pos);
                }
            }
        }

        let quest = self.cfg.policy == PolicyKind::Quest;
        // reads observed by this step (before the new token is written)
        let mut live_before = vec![0f64; b];
        let mut pages_before = vec![0usize; b];
        for lane in 0..b {
            if lanes[lane].is_some() {
                live_before[lane] = self.cache.live_tokens(lane);
                if quest {
                    let mut pages = 0;
                    for li in 0..l {
                        for hi in 0..h {
                            pages += self.cache.allocated_pages(lane, li, hi);
                        }
                    }
                    pages_before[lane] = pages;
                }
            }
        }

        let t0 = Instant::now();
        let out = match &self.param_bufs {
            Some(pb) => self.decode_exec.decode_buffered(
                pb,
                self.cache.k_slice(),
                self.cache.v_slice(),
                &tokens,
                &positions,
                self.cache.mask_slice(),
                self.cache.pmin_slice(),
                self.cache.pmax_slice(),
                quest_k,
                &self.geom,
            )?,
            None => self.decode_exec.decode(
                self.weights.literals(),
                self.cache.k_slice(),
                self.cache.v_slice(),
                &tokens,
                &positions,
                self.cache.mask_slice(),
                self.cache.pmin_slice(),
                self.cache.pmax_slice(),
                quest_k,
                &self.geom,
            )?,
        };
        stats.executor_s += t0.elapsed().as_secs_f64();

        let pages_total = self.geom.pages();
        let mut alpha_lane = vec![0f32; lh];
        let mut attn_lane = vec![0f32; lh * s];
        let mut attn_self_lane = vec![0f32; lh];
        let mut actions: Vec<WriteAction> = Vec::with_capacity(lh);
        let mut written: Vec<Option<usize>> = vec![None; lh];

        for lane in 0..b {
            let Some(a) = lanes[lane].as_mut() else { continue };
            if !matches!(a.phase, Phase::Decode) {
                continue;
            }
            // gather per-lane views from the batched outputs
            for li in 0..l {
                for hi in 0..h {
                    let src = (li * b + lane) * h + hi;
                    alpha_lane[li * h + hi] = out.alpha[src];
                    attn_self_lane[li * h + hi] = out.attn_self[src];
                    attn_lane[(li * h + hi) * s..(li * h + hi + 1) * s]
                        .copy_from_slice(&out.attn[src * s..(src + 1) * s]);
                }
            }

            // ---- reads accounting (§5.1) ----
            if quest {
                let mut sel_pages = 0usize;
                for li in 0..l {
                    for hi in 0..h {
                        let base = ((li * b + lane) * h + hi) * pages_total;
                        sel_pages += out.qsel[base..base + pages_total]
                            .iter()
                            .filter(|&&x| x > 0.5)
                            .count();
                    }
                }
                let page_reads =
                    sel_pages as f64 * self.geom.page_size as f64 / lh as f64;
                let meta_reads = pages_before[lane] as f64
                    * crate::compress::quest::QuestPolicy::META_TOKENS_PER_PAGE
                    / lh as f64;
                a.stats.decode_reads += page_reads.min(live_before[lane]) + meta_reads + 1.0;
            } else {
                a.stats.decode_reads += live_before[lane] + 1.0;
            }

            // ---- write the new token ----
            a.policy.write_actions(&alpha_lane, l, h, &mut actions);
            let mut overflow = false;
            for li in 0..l {
                for hi in 0..h {
                    let i = li * h + hi;
                    let base = ((li * b) + lane) * h + hi;
                    let kk = &out.k_new[base * hd..(base + 1) * hd];
                    let vv = &out.v_new[base * hd..(base + 1) * hd];
                    written[i] = None;
                    match actions[i] {
                        WriteAction::Merge => {
                            if !self.cache.merge_into_last(lane, li, hi, kk, vv) {
                                // nothing to merge into: fall back to append
                                match self.cache.alloc_slot(lane, li, hi) {
                                    Some(slot) => {
                                        self.cache
                                            .write(lane, li, hi, slot, a.pos, kk, vv);
                                        written[i] = Some(slot);
                                    }
                                    None => overflow = true,
                                }
                            }
                        }
                        WriteAction::Append => match self.cache.alloc_slot(lane, li, hi) {
                            Some(slot) => {
                                self.cache.write(lane, li, hi, slot, a.pos, kk, vv);
                                written[i] = Some(slot);
                            }
                            None => overflow = true,
                        },
                    }
                }
            }

            let view = StepView {
                lane,
                pos: a.pos,
                alpha: &alpha_lane,
                attn: &attn_lane,
                attn_self: &attn_self_lane,
                written: &written,
            };
            a.policy.post_write(&mut self.cache, &view);

            // ---- per-chain bookkeeping ----
            let evict_decisions =
                alpha_lane.iter().filter(|&&x| x > 0.5).count() as u16;
            a.stats.evictions_per_pos.push(evict_decisions);
            let mut peak = self.cache.live_tokens(lane);
            if quest {
                let mut pages = 0;
                for li in 0..l {
                    for hi in 0..h {
                        pages += self.cache.allocated_pages(lane, li, hi);
                    }
                }
                peak += pages as f64
                    * crate::compress::quest::QuestPolicy::META_TOKENS_PER_PAGE
                    / lh as f64;
            }
            if peak > a.stats.peak_tokens {
                a.stats.peak_tokens = peak;
            }

            // ---- sample next token & check termination ----
            let logits = &out.logits[lane * v..(lane + 1) * v];
            let tok = a.sampler.sample(logits);
            a.gen_ids.push(a.cur_token);
            a.pos += 1;
            a.cur_token = tok;

            let finish = if overflow {
                Some(FinishReason::Overflow)
            } else if tok == EOS_ID || tok == self.newline_id {
                if tok == self.newline_id {
                    a.gen_ids.push(tok);
                }
                Some(FinishReason::Stop)
            } else if a.pos + 1 >= a.max_len {
                a.gen_ids.push(tok);
                Some(FinishReason::Length)
            } else {
                None
            };

            if let Some(reason) = finish {
                let a = lanes[lane].take().unwrap();
                self.finish_chain(a, lane, reason, results);
            }
        }
        Ok(())
    }

    fn lane_peak_tokens(&self, lane: usize) -> f64 {
        self.cache.live_tokens(lane)
    }

    fn finish_chain(
        &mut self,
        mut a: Active,
        lane: usize,
        finish: FinishReason,
        results: &mut [Vec<Option<ChainResult>>],
    ) {
        let (l, h) = (self.geom.layers, self.geom.kv_heads);
        let mut retained = Vec::with_capacity(l * h);
        for li in 0..l {
            for hi in 0..h {
                retained.push((self.cache.live_count(lane, li, hi), a.pos));
            }
        }
        a.stats.retained_per_lh = retained;
        a.stats.final_tokens = self.cache.live_tokens(lane);
        a.stats.gen_tokens = a.gen_ids.len().saturating_sub(a.prompt_ids.len().min(0));
        a.stats.gen_tokens = a.gen_ids.len();
        a.stats.wall_s = a.started.elapsed().as_secs_f64();
        // generated text excludes the prompt (gen_ids holds only
        // generated tokens)
        let text = self.tokenizer.decode(&a.gen_ids);
        self.cache.reset_lane(lane);
        results[a.req_idx][a.chain_idx] = Some(ChainResult {
            text,
            finish,
            stats: a.stats,
        });
    }

    /// Convenience: run a single request.
    pub fn generate(&mut self, req: GenRequest) -> Result<GenResult> {
        let (mut out, _) = self.run(std::slice::from_ref(&req))?;
        Ok(out.remove(0))
    }

    /// Open an engine from an artifacts path with defaults.
    pub fn open(artifacts: &Path) -> Result<Self> {
        Engine::new(EngineConfig {
            artifacts: artifacts.to_path_buf(),
            ..Default::default()
        })
    }
}
