//! Trace-driven hyperscale load generator.
//!
//! Seed-deterministic request streams for the SLO serving stack: an
//! arrival process ([`ArrivalKind`]) times requests, a weighted mix
//! draws a [`RequestClass`] per request (chat, long-context, parallel
//! width-W voting), and zipf prompt reuse makes prefix caching matter
//! at scale. The per-request draw order is **fixed** — gap, class,
//! prompt id, gen tokens — so draw totals are a pure function of the
//! stream position and `tools/seed_bench_slo.py` can mirror them
//! bit-for-bit without re-implementing `ln` (the one float that feeds
//! exponential gaps affects only arrival *times*, never which value
//! the next draw produces).
//!
//! Each class carries an [`SloTier`], so a generated stream is ready
//! for `timeflow::simulate_slo` via [`slo_requests`] (width-W voting
//! flattens into W chains sharing arrival, prompt, and deadlines).
//! Prompt ids are namespaced per class (`class_idx × n_prompts + id`)
//! so a prompt id always maps to one token length — the invariant the
//! prefix-reuse model relies on.

use anyhow::{anyhow, Error};

use super::slo::{SloRequest, SloTier};
use super::timeflow::SimRequest;
use crate::util::rng::SplitMix64;

/// Diurnal load curve: relative arrival-rate divisors over eight
/// equal phases of the stream (1 = mean gap, 8 = one-eighth the
/// traffic — gaps are *multiplied*, so larger means quieter).
pub const DIURNAL_GAP_MULT: [u64; 8] = [1, 1, 2, 4, 8, 4, 2, 1];

/// Arrival process for the generated stream. Extends the timeflow
/// processes with a diurnal (time-of-day) curve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Fixed inter-arrival gap (`mean_gap_ns` exactly); consumes no
    /// RNG draw, so arrival times are integer-exact and mirrorable.
    Uniform,
    /// Exponential inter-arrival gaps (Poisson process).
    Poisson,
    /// Bursts of `burst` simultaneous arrivals, exponential gaps
    /// between bursts.
    Bursty,
    /// Poisson with the mean gap scaled by [`DIURNAL_GAP_MULT`] across
    /// eight equal phases of the request stream.
    Diurnal,
}

impl std::str::FromStr for ArrivalKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "uniform" => Ok(ArrivalKind::Uniform),
            "poisson" => Ok(ArrivalKind::Poisson),
            "bursty" => Ok(ArrivalKind::Bursty),
            "diurnal" => Ok(ArrivalKind::Diurnal),
            other => Err(anyhow!(
                "unknown arrival process '{other}' (uniform|poisson|bursty|diurnal)"
            )),
        }
    }
}

impl ArrivalKind {
    /// All processes, in the order the bench/seeder iterate them.
    pub const ALL: [ArrivalKind; 4] = [
        ArrivalKind::Uniform,
        ArrivalKind::Poisson,
        ArrivalKind::Bursty,
        ArrivalKind::Diurnal,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Uniform => "uniform",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
        }
    }
}

/// Request class in the serving mix. Class decides token ranges,
/// parallel width, and the SLO tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestClass {
    /// Short prompt, short generation, width 1, interactive deadlines.
    Chat,
    /// Long prompt, moderate generation, width 1, batch deadlines.
    LongContext,
    /// Chat-sized tokens fanned out to `vote_width` parallel chains
    /// (the paper's parallel-scaling width W), standard deadlines.
    Voting,
}

impl RequestClass {
    /// All classes, in mix-weight order.
    pub const ALL: [RequestClass; 3] =
        [RequestClass::Chat, RequestClass::LongContext, RequestClass::Voting];

    pub fn name(&self) -> &'static str {
        match self {
            RequestClass::Chat => "chat",
            RequestClass::LongContext => "long_context",
            RequestClass::Voting => "voting",
        }
    }

    /// SLO tier this class serves under.
    pub fn tier(&self) -> SloTier {
        match self {
            RequestClass::Chat => SloTier::Interactive,
            RequestClass::LongContext => SloTier::Batch,
            RequestClass::Voting => SloTier::Standard,
        }
    }

    /// Inclusive prompt-token range.
    pub fn prompt_tokens(&self) -> (usize, usize) {
        match self {
            RequestClass::Chat | RequestClass::Voting => (32, 96),
            RequestClass::LongContext => (256, 768),
        }
    }

    /// Inclusive generated-token range.
    pub fn gen_tokens(&self) -> (usize, usize) {
        match self {
            RequestClass::Chat | RequestClass::Voting => (16, 64),
            RequestClass::LongContext => (32, 96),
        }
    }
}

/// Mixed-workload description: fully determined by `seed`.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadConfig {
    pub requests: usize,
    pub seed: u64,
    pub arrival: ArrivalKind,
    /// Mean inter-arrival gap (per request, all replicas combined).
    pub mean_gap_ns: u64,
    /// Burst width for [`ArrivalKind::Bursty`].
    pub burst: usize,
    /// Distinct prompts *per class*; ids drawn zipf(`zipf_s`) and
    /// namespaced per class.
    pub n_prompts: usize,
    pub zipf_s: f64,
    /// Mix weights over [`RequestClass::ALL`] (chat, long-context,
    /// voting); normalized by the weighted draw.
    pub mix: [f64; 3],
    /// Parallel chains per [`RequestClass::Voting`] request.
    pub vote_width: usize,
}

impl WorkloadConfig {
    /// Default mix: 70% chat / 20% long-context / 10% width-4 voting,
    /// Poisson arrivals.
    pub fn new(requests: usize, seed: u64) -> Self {
        WorkloadConfig {
            requests,
            seed,
            arrival: ArrivalKind::Poisson,
            mean_gap_ns: 1_250_000,
            burst: 32,
            n_prompts: 64,
            zipf_s: 1.0,
            mix: [0.70, 0.20, 0.10],
            vote_width: 4,
        }
    }
}

/// One generated request, cycle-stamped and class/tier-tagged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkloadRequest {
    pub arrival_ns: u64,
    pub class: RequestClass,
    pub tier: SloTier,
    /// Parallel chains (1 except for voting requests).
    pub width: usize,
    /// Class-namespaced prompt id (`class_idx × n_prompts + draw`).
    pub prompt_id: usize,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
}

/// Zipf weights (same closed form as the timeflow generator: `s == 1`
/// avoids `powf` so the seeder mirrors it exactly).
fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    (1..=n)
        .map(|k| {
            if s == 1.0 {
                1.0 / k as f64
            } else {
                (k as f64).powf(-s)
            }
        })
        .collect()
}

/// Generate the mixed workload for `cfg`. Per-request draw order is
/// fixed — gap, class, prompt id, gen tokens — so totals are
/// mirror-computable at every stream position.
pub fn generate_mixed_workload(cfg: &WorkloadConfig) -> Vec<WorkloadRequest> {
    assert!(cfg.requests > 0 && cfg.n_prompts > 0);
    assert!(cfg.vote_width >= 1);
    assert!(cfg.mix.iter().all(|&w| w >= 0.0) && cfg.mix.iter().sum::<f64>() > 0.0);
    let mut rng = SplitMix64::new(cfg.seed);
    let zipf = zipf_weights(cfg.n_prompts, cfg.zipf_s);
    let exp_gap = |rng: &mut SplitMix64, mean: u64| -> u64 {
        let u = rng.f64();
        (-(1.0 - u).ln() * mean as f64).round() as u64
    };
    let diurnal_phase_len = (cfg.requests / DIURNAL_GAP_MULT.len()).max(1);
    let mut t = 0u64;
    let mut out = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        t += match cfg.arrival {
            ArrivalKind::Uniform => cfg.mean_gap_ns,
            ArrivalKind::Poisson => exp_gap(&mut rng, cfg.mean_gap_ns),
            ArrivalKind::Bursty => {
                if i % cfg.burst.max(1) == 0 {
                    exp_gap(&mut rng, cfg.mean_gap_ns * cfg.burst.max(1) as u64)
                } else {
                    0
                }
            }
            ArrivalKind::Diurnal => {
                let phase = (i / diurnal_phase_len) % DIURNAL_GAP_MULT.len();
                exp_gap(&mut rng, cfg.mean_gap_ns * DIURNAL_GAP_MULT[phase])
            }
        };
        let class_idx = rng.weighted(&cfg.mix);
        let class = RequestClass::ALL[class_idx];
        let raw_id = rng.weighted(&zipf);
        let prompt_id = class_idx * cfg.n_prompts + raw_id;
        let (p_lo, p_hi) = class.prompt_tokens();
        let prompt_tokens = p_lo + (raw_id * 37) % (p_hi - p_lo + 1);
        let (g_lo, g_hi) = class.gen_tokens();
        let gen_tokens = g_lo + rng.below(g_hi - g_lo + 1);
        let width = match class {
            RequestClass::Voting => cfg.vote_width,
            _ => 1,
        };
        out.push(WorkloadRequest {
            arrival_ns: t,
            class,
            tier: class.tier(),
            width,
            prompt_id,
            prompt_tokens,
            gen_tokens,
        });
    }
    out
}

/// Flatten a mixed workload into deadline-stamped sim requests: a
/// width-W voting request becomes W chains sharing arrival, prompt,
/// and deadlines (each chain demands its own KV bytes — parallel
/// scaling multiplies load, which is the point).
pub fn slo_requests(reqs: &[WorkloadRequest]) -> Vec<SloRequest> {
    let mut out = Vec::with_capacity(reqs.len());
    for r in reqs {
        for _ in 0..r.width.max(1) {
            out.push(SloRequest::stamp(
                SimRequest {
                    arrival_ns: r.arrival_ns,
                    prompt_id: r.prompt_id,
                    prompt_tokens: r.prompt_tokens,
                    gen_tokens: r.gen_tokens,
                },
                r.tier,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEED: u64 = 0x510_AD;

    fn cfg(arrival: ArrivalKind) -> WorkloadConfig {
        WorkloadConfig {
            arrival,
            ..WorkloadConfig::new(4096, SEED)
        }
    }

    /// (Σ prompt_tokens, Σ gen_tokens, chat, long_context, voting) —
    /// golden values mirrored by tools/seed_bench_slo.py; the arrival
    /// process changes how many gap draws precede each request's
    /// class/prompt/gen draws, so each process pins its own totals.
    fn draw_totals(reqs: &[WorkloadRequest]) -> (usize, usize, usize, usize, usize) {
        let p: usize = reqs.iter().map(|r| r.prompt_tokens).sum();
        let g: usize = reqs.iter().map(|r| r.gen_tokens).sum();
        let count = |c: RequestClass| -> usize { reqs.iter().filter(|r| r.class == c).count() };
        (
            p,
            g,
            count(RequestClass::Chat),
            count(RequestClass::LongContext),
            count(RequestClass::Voting),
        )
    }

    #[test]
    fn per_process_draw_totals_are_pinned() {
        // mirrored bit-for-bit by tools/seed_bench_slo.py (PR-6 seeder
        // pattern): a drift in draw order or RNG use fails here first.
        let golden = [
            (ArrivalKind::Uniform, GOLDEN_UNIFORM),
            (ArrivalKind::Poisson, GOLDEN_POISSON),
            (ArrivalKind::Bursty, GOLDEN_BURSTY),
            (ArrivalKind::Diurnal, GOLDEN_DIURNAL),
        ];
        for (arrival, want) in golden {
            let reqs = generate_mixed_workload(&cfg(arrival));
            assert_eq!(draw_totals(&reqs), want, "arrival {}", arrival.name());
        }
    }

    const GOLDEN_UNIFORM: (usize, usize, usize, usize, usize) = (523956, 185181, 2846, 820, 430);
    const GOLDEN_POISSON: (usize, usize, usize, usize, usize) = (522938, 183742, 2866, 818, 412);
    const GOLDEN_BURSTY: (usize, usize, usize, usize, usize) = (538826, 184713, 2833, 862, 401);
    const GOLDEN_DIURNAL: (usize, usize, usize, usize, usize) = (522938, 183742, 2866, 818, 412);

    #[test]
    fn same_seed_is_bit_identical() {
        for arrival in ArrivalKind::ALL {
            let a = generate_mixed_workload(&cfg(arrival));
            let b = generate_mixed_workload(&cfg(arrival));
            assert_eq!(a, b, "arrival {}", arrival.name());
        }
    }

    #[test]
    fn classes_stay_in_range_with_correct_width_and_tier() {
        let reqs = generate_mixed_workload(&cfg(ArrivalKind::Poisson));
        let mut seen = [false; 3];
        for r in &reqs {
            let (p_lo, p_hi) = r.class.prompt_tokens();
            let (g_lo, g_hi) = r.class.gen_tokens();
            assert!(r.prompt_tokens >= p_lo && r.prompt_tokens <= p_hi);
            assert!(r.gen_tokens >= g_lo && r.gen_tokens <= g_hi);
            assert_eq!(r.tier, r.class.tier());
            match r.class {
                RequestClass::Chat => {
                    seen[0] = true;
                    assert_eq!(r.width, 1);
                    assert!(r.prompt_id < 64);
                }
                RequestClass::LongContext => {
                    seen[1] = true;
                    assert_eq!(r.width, 1);
                    assert!((64..128).contains(&r.prompt_id));
                }
                RequestClass::Voting => {
                    seen[2] = true;
                    assert_eq!(r.width, 4);
                    assert!((128..192).contains(&r.prompt_id));
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "every class appears at 4096 draws");
    }

    #[test]
    fn uniform_arrivals_are_integer_exact() {
        let reqs = generate_mixed_workload(&cfg(ArrivalKind::Uniform));
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.arrival_ns, (i as u64 + 1) * 1_250_000);
        }
    }

    #[test]
    fn gap_draw_alignment_keeps_poisson_and_diurnal_streams_equal() {
        // both consume exactly one gap draw per request, so the
        // class/prompt/gen streams coincide — only arrival times move.
        let p = generate_mixed_workload(&cfg(ArrivalKind::Poisson));
        let d = generate_mixed_workload(&cfg(ArrivalKind::Diurnal));
        for (a, b) in p.iter().zip(&d) {
            assert_eq!((a.class, a.prompt_id, a.gen_tokens), (b.class, b.prompt_id, b.gen_tokens));
        }
    }

    #[test]
    fn slo_requests_flatten_voting_width() {
        let reqs = generate_mixed_workload(&cfg(ArrivalKind::Uniform));
        let flat = slo_requests(&reqs);
        let want: usize = reqs.iter().map(|r| r.width).sum();
        assert_eq!(flat.len(), want);
        let mut i = 0;
        for r in &reqs {
            for _ in 0..r.width {
                let s = &flat[i];
                assert_eq!(s.sim.arrival_ns, r.arrival_ns);
                assert_eq!(s.sim.prompt_id, r.prompt_id);
                assert_eq!(s.tier, r.tier);
                assert_eq!(s.ttft_deadline_ns, r.arrival_ns + r.tier.ttft_deadline_ns());
                assert_eq!(s.e2e_deadline_ns, r.arrival_ns + r.tier.e2e_deadline_ns());
                i += 1;
            }
        }
    }
}
