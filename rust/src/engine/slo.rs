//! SLO classes and utilization-based admission control.
//!
//! The paper's hyper-scaling claim — *more tokens within the same
//! compute budget* — only becomes measurable once requests carry
//! deadlines: compression frees KV bytes, and this module converts
//! those bytes into **admissible load**. Every request is assigned an
//! [`SloTier`] (TTFT + end-to-end deadline pair); an
//! [`AdmissionController`] prices each request in KV bytes via the
//! timeflow [`CostModel`] (itself derived from the App. G latency
//! model) and accepts, queues, or rejects against a byte capacity that
//! is **dtype-independent**. Demand *is* dtype-dependent, so switching
//! pool payloads from f32 to q8/q4 shrinks per-request demand ~4–7×
//! and the same capacity admits strictly more load — the hyper-scaling
//! dividend as an admission-counter delta (`BENCH_slo.json` pins it).
//!
//! Dispatch ordering among admitted requests is EDF (earliest e2e
//! deadline first) with deterministic tie-breaks on request id — see
//! `AdmissionPolicy::Edf` in the scheduler and the EDF queue scan in
//! `timeflow::simulate_slo`. Preemption never victimizes a stricter
//! tier for a looser one (scheduler invariant, property-tested in
//! `tests/slo_admission.rs`).
//!
//! Everything here is integer arithmetic over u64 nanoseconds/bytes,
//! so admission decisions on an integer-stamped arrival stream are a
//! closed form that `tools/seed_bench_slo.py` mirrors bit-for-bit.

use std::str::FromStr;

use anyhow::{anyhow, Error};

use super::timeflow::{CostModel, SimRequest};
use crate::compress::AllocatorKind;
use crate::kvcache::KvDtype;

/// Resident-token budget per lane used to size the admission byte
/// capacity: how many tokens a lane is provisioned to keep live at
/// once (prompt + generation for a typical long request).
pub const LANE_RESIDENT_TOKENS: u64 = 1024;

/// Multiplier from a request's uncontended service time to its
/// capacity-commitment window: admitted bytes stay committed for
/// `SERVICE_WINDOW_SLACK ×` the analytic service time, covering
/// queueing and lane contention without modeling them.
pub const SERVICE_WINDOW_SLACK: u64 = 4;

/// Per-request SLO class: a (TTFT, e2e) deadline pair. Lower variants
/// are *stricter* — the derived `Ord` gives priority order, so
/// `Interactive < Standard < Batch` and "never preempt a higher tier
/// for a lower one" is a plain `<` on tiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloTier {
    /// Chat-style turn: first token must feel instant.
    Interactive,
    /// Parallel-width voting and tooling calls: bounded but relaxed.
    Standard,
    /// Long-context ingestion and offline scoring: throughput tier.
    Batch,
}

impl SloTier {
    /// All tiers, strictest first.
    pub const ALL: [SloTier; 3] = [SloTier::Interactive, SloTier::Standard, SloTier::Batch];

    pub fn name(&self) -> &'static str {
        match self {
            SloTier::Interactive => "interactive",
            SloTier::Standard => "standard",
            SloTier::Batch => "batch",
        }
    }

    /// Time-to-first-token deadline, as an offset from arrival.
    pub fn ttft_deadline_ns(&self) -> u64 {
        match self {
            SloTier::Interactive => 20_000_000, // 20 ms
            SloTier::Standard => 100_000_000,   // 100 ms
            SloTier::Batch => 1_000_000_000,    // 1 s
        }
    }

    /// End-to-end completion deadline, as an offset from arrival.
    pub fn e2e_deadline_ns(&self) -> u64 {
        match self {
            SloTier::Interactive => 50_000_000, // 50 ms
            SloTier::Standard => 250_000_000,   // 250 ms
            SloTier::Batch => 2_500_000_000,    // 2.5 s
        }
    }
}

impl FromStr for SloTier {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "interactive" => Ok(SloTier::Interactive),
            "standard" => Ok(SloTier::Standard),
            "batch" => Ok(SloTier::Batch),
            other => Err(anyhow!(
                "unknown SLO tier '{other}' (interactive|standard|batch)"
            )),
        }
    }
}

/// One deadline-stamped simulation request: a timeflow [`SimRequest`]
/// plus its tier and *absolute* deadlines (arrival + tier offsets).
#[derive(Clone, Copy, Debug)]
pub struct SloRequest {
    pub sim: SimRequest,
    pub tier: SloTier,
    /// Absolute TTFT deadline (`arrival_ns + tier.ttft_deadline_ns()`).
    pub ttft_deadline_ns: u64,
    /// Absolute e2e deadline (`arrival_ns + tier.e2e_deadline_ns()`).
    pub e2e_deadline_ns: u64,
}

impl SloRequest {
    /// Stamp a sim request with a tier's absolute deadlines.
    pub fn stamp(sim: SimRequest, tier: SloTier) -> Self {
        SloRequest {
            sim,
            tier,
            ttft_deadline_ns: sim.arrival_ns + tier.ttft_deadline_ns(),
            e2e_deadline_ns: sim.arrival_ns + tier.e2e_deadline_ns(),
        }
    }
}

/// Scheduling/admission policy for an SLO simulation run.
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Dispatch queued requests earliest-e2e-deadline-first (tie-break
    /// on request index) instead of FCFS.
    pub edf: bool,
    /// Gate arrivals through an [`AdmissionController`]; when false
    /// every request is accepted (pure-EDF ablation).
    pub admission: bool,
    /// Byte capacity for the controller (see [`byte_capacity`]).
    pub capacity_bytes: u64,
}

impl SloPolicy {
    /// EDF + admission at the capacity for `replicas × lanes`.
    pub fn edf_admitted(replicas: usize, lanes: usize) -> Self {
        SloPolicy {
            edf: true,
            admission: true,
            capacity_bytes: byte_capacity(replicas, lanes),
        }
    }

    /// FCFS without admission — the pre-SLO baseline.
    pub fn fcfs_open(replicas: usize, lanes: usize) -> Self {
        SloPolicy {
            edf: false,
            admission: false,
            capacity_bytes: byte_capacity(replicas, lanes),
        }
    }
}

/// Admission byte capacity for a cluster: every lane is provisioned
/// for [`LANE_RESIDENT_TOKENS`] resident tokens **at f32 payload
/// bytes**. Deliberately dtype-independent: the hardware pool does not
/// grow when payloads quantize — per-request *demand* shrinks instead,
/// which is exactly how compression converts into admissible load.
pub fn byte_capacity(replicas: usize, lanes: usize) -> u64 {
    let f32_bytes =
        CostModel::default_for(KvDtype::F32, AllocatorKind::Uniform).kv_bytes_per_token;
    replicas as u64 * lanes as u64 * LANE_RESIDENT_TOKENS * f32_bytes
}

/// Outcome of offering one request to the admission controller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Fits in capacity now: dispatch immediately.
    Accept,
    /// Over capacity but within the 2× queueing headroom: enqueue.
    Queue,
    /// Over even the queueing headroom: reject at arrival.
    Reject,
}

/// Utilization-based admission over a byte-capacity ledger.
///
/// Each offered request demands `(prompt + gen) × kv_bytes_per_token`
/// bytes for a commitment window of `SERVICE_WINDOW_SLACK ×` its
/// analytic service time (`prompt × prefill_ns + gen × decode_ns`).
/// Accepted commitments never exceed `capacity_bytes` — the analytic
/// utilization of the accepted set is ≤ 1 **by construction** (the
/// property suite re-checks it at every step). Queued commitments may
/// use a further `capacity_bytes` of headroom at a doubled window;
/// beyond that the request is rejected outright.
///
/// All arithmetic is u64, so the accept/queue/reject stream for an
/// integer arrival stream is a closed form (`tools/seed_bench_slo.py`).
#[derive(Clone, Debug)]
pub struct AdmissionController {
    capacity_bytes: u64,
    cost: CostModel,
    /// Live commitments: `(expiry_ns, bytes, accepted)`.
    ledger: Vec<(u64, u64, bool)>,
    accepted_bytes: u64,
    queued_bytes: u64,
    accepted: u64,
    queued: u64,
    rejected: u64,
}

impl AdmissionController {
    pub fn new(capacity_bytes: u64, cost: CostModel) -> Self {
        assert!(capacity_bytes > 0, "admission capacity must be nonzero");
        AdmissionController {
            capacity_bytes,
            cost,
            ledger: Vec::new(),
            accepted_bytes: 0,
            queued_bytes: 0,
            accepted: 0,
            queued: 0,
            rejected: 0,
        }
    }

    /// KV-byte demand of one request under this controller's dtype.
    pub fn demand_bytes(&self, prompt_tokens: usize, gen_tokens: usize) -> u64 {
        (prompt_tokens + gen_tokens) as u64 * self.cost.kv_bytes_per_token
    }

    /// Commitment window: slack × analytic uncontended service time.
    pub fn window_ns(&self, prompt_tokens: usize, gen_tokens: usize) -> u64 {
        let service = prompt_tokens as u64 * self.cost.prefill_ns
            + gen_tokens as u64 * self.cost.decode_ns;
        service * SERVICE_WINDOW_SLACK
    }

    fn expire(&mut self, now_ns: u64) {
        let (mut freed_acc, mut freed_q) = (0u64, 0u64);
        self.ledger.retain(|&(expiry, bytes, accepted)| {
            if expiry <= now_ns {
                if accepted {
                    freed_acc += bytes;
                } else {
                    freed_q += bytes;
                }
                false
            } else {
                true
            }
        });
        self.accepted_bytes -= freed_acc;
        self.queued_bytes -= freed_q;
    }

    /// Offer one request arriving at `now_ns`; returns the decision
    /// and updates the ledger/counters. Offers must be made in
    /// nondecreasing `now_ns` order (arrival order).
    pub fn offer(
        &mut self,
        now_ns: u64,
        prompt_tokens: usize,
        gen_tokens: usize,
    ) -> AdmissionDecision {
        self.expire(now_ns);
        let d = self.demand_bytes(prompt_tokens, gen_tokens);
        let w = self.window_ns(prompt_tokens, gen_tokens);
        if self.accepted_bytes + d <= self.capacity_bytes {
            self.ledger.push((now_ns + w, d, true));
            self.accepted_bytes += d;
            self.accepted += 1;
            AdmissionDecision::Accept
        } else if self.accepted_bytes + self.queued_bytes + d <= 2 * self.capacity_bytes {
            self.ledger.push((now_ns + 2 * w, d, false));
            self.queued_bytes += d;
            self.queued += 1;
            AdmissionDecision::Queue
        } else {
            self.rejected += 1;
            AdmissionDecision::Reject
        }
    }

    /// Analytic utilization of the *accepted* set (≤ 1 by construction).
    pub fn utilization(&self) -> f64 {
        self.accepted_bytes as f64 / self.capacity_bytes as f64
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    pub fn queued(&self) -> u64 {
        self.queued
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// accepted + queued + rejected — equals offers made (conservation).
    pub fn offered(&self) -> u64 {
        self.accepted + self.queued + self.rejected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(dtype: KvDtype) -> CostModel {
        CostModel::default_for(dtype, AllocatorKind::Uniform)
    }

    #[test]
    fn tiers_order_strictest_first() {
        assert!(SloTier::Interactive < SloTier::Standard);
        assert!(SloTier::Standard < SloTier::Batch);
        for w in SloTier::ALL.windows(2) {
            assert!(w[0].ttft_deadline_ns() < w[1].ttft_deadline_ns());
            assert!(w[0].e2e_deadline_ns() < w[1].e2e_deadline_ns());
            assert!(w[0].ttft_deadline_ns() < w[0].e2e_deadline_ns());
        }
    }

    #[test]
    fn tier_names_round_trip() {
        for tier in SloTier::ALL {
            assert_eq!(tier.name().parse::<SloTier>().unwrap(), tier);
        }
        assert!("gold".parse::<SloTier>().is_err());
    }

    #[test]
    fn stamp_offsets_deadlines_from_arrival() {
        let sim = SimRequest {
            arrival_ns: 1_000,
            prompt_id: 0,
            prompt_tokens: 32,
            gen_tokens: 16,
        };
        let r = SloRequest::stamp(sim, SloTier::Interactive);
        assert_eq!(r.ttft_deadline_ns, 1_000 + 20_000_000);
        assert_eq!(r.e2e_deadline_ns, 1_000 + 50_000_000);
    }

    #[test]
    fn admission_accepts_then_queues_then_rejects() {
        // capacity for exactly two requests' demand
        let c = cost(KvDtype::F32);
        let demand = 48 * c.kv_bytes_per_token;
        let mut ctl = AdmissionController::new(2 * demand, c);
        // all at t=0: 2 accepts, 2 queues (2× headroom), then rejects
        assert_eq!(ctl.offer(0, 32, 16), AdmissionDecision::Accept);
        assert_eq!(ctl.offer(0, 32, 16), AdmissionDecision::Accept);
        assert_eq!(ctl.offer(0, 32, 16), AdmissionDecision::Queue);
        assert_eq!(ctl.offer(0, 32, 16), AdmissionDecision::Queue);
        assert_eq!(ctl.offer(0, 32, 16), AdmissionDecision::Reject);
        assert_eq!(ctl.offered(), 5);
        assert_eq!((ctl.accepted(), ctl.queued(), ctl.rejected()), (2, 2, 1));
        assert!(ctl.utilization() <= 1.0);
    }

    #[test]
    fn expired_commitments_free_capacity() {
        let c = cost(KvDtype::F32);
        let demand = 48 * c.kv_bytes_per_token;
        let window = (32 * c.prefill_ns + 16 * c.decode_ns) * SERVICE_WINDOW_SLACK;
        let mut ctl = AdmissionController::new(demand, c);
        assert_eq!(ctl.window_ns(32, 16), window);
        assert_eq!(ctl.offer(0, 32, 16), AdmissionDecision::Accept);
        // within the window capacity is held...
        assert_ne!(ctl.offer(window - 1, 32, 16), AdmissionDecision::Accept);
        // ...and past it the commitment expires and frees the bytes
        assert_eq!(ctl.offer(window + 1, 32, 16), AdmissionDecision::Accept);
        assert_eq!(ctl.accepted(), 2);
    }

    #[test]
    fn q4_admits_strictly_more_than_f32_at_same_capacity() {
        let capacity = byte_capacity(1, 1);
        let mut f32_ctl = AdmissionController::new(capacity, cost(KvDtype::F32));
        let mut q4_ctl = AdmissionController::new(capacity, cost(KvDtype::Q4));
        // an instantaneous burst: only byte demand differentiates
        for _ in 0..64 {
            f32_ctl.offer(0, 32, 16);
            q4_ctl.offer(0, 32, 16);
        }
        assert!(
            q4_ctl.accepted() > f32_ctl.accepted(),
            "q4 {} vs f32 {}",
            q4_ctl.accepted(),
            f32_ctl.accepted()
        );
    }
}
