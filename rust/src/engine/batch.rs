//! Step-batch assembly: packing active lanes into executor inputs and
//! fanning per-lane host work out across scoped threads.
//!
//! One scheduler *tick* may carry both a prefill chunk (for lanes still
//! consuming their prompt) and a decode step (for lanes generating) —
//! the AOT artifacts export prefill and decode as separate programs, so
//! a mixed tick issues both back-to-back instead of stalling decode
//! lanes behind prefill as the pre-refactor engine did.
//!
//! After the executor returns, the per-lane host work — gathering the
//! lane's α/attention views from the batched outputs, asking the
//! compression policy for its write actions, and sampling the next
//! token — is independent across lanes (each lane owns its policy and
//! sampler, and reads disjoint slices of the outputs), so it runs on
//! scoped threads, one per active lane. Only the cache writes
//! themselves are applied sequentially afterwards: the `CacheStore`'s
//! flat arrays interleave lanes within each layer, and the write volume
//! is memcpy-bound anyway. Results are collected in lane order, so
//! threading never changes observable behaviour.

use super::scheduler::{ChainState, Phase};
use crate::compress::WriteAction;
use crate::kvcache::Geometry;
use crate::runtime::DecodeOutputs;

/// Executor inputs for one prefill chunk across all prefilling lanes.
pub struct PrefillBatch {
    /// i32[B, C] token ids (PAD on inactive positions).
    pub tokens: Vec<i32>,
    /// i32[B, C] absolute positions.
    pub positions: Vec<i32>,
    /// f32[B, C] validity mask (1.0 = real token).
    pub valid: Vec<f32>,
    /// Tokens packed for each lane this chunk (0 = lane not prefilling).
    pub chunk_lens: Vec<usize>,
}

impl PrefillBatch {
    /// True when no lane had prompt tokens left to pack.
    pub fn is_empty(&self) -> bool {
        self.chunk_lens.iter().all(|&n| n == 0)
    }

    /// Prompt tokens packed across all lanes this chunk — the engine's
    /// per-tick prefill-volume accounting (`engine.prefill_tokens`).
    pub fn total_tokens(&self) -> usize {
        self.chunk_lens.iter().sum()
    }
}

/// Pack up to `chunk` prompt tokens per prefilling lane.
pub fn assemble_prefill(
    lanes: &[Option<ChainState>],
    batch: usize,
    chunk: usize,
    pad: i32,
) -> PrefillBatch {
    let mut tokens = vec![pad; batch * chunk];
    let mut positions = vec![0i32; batch * chunk];
    let mut valid = vec![0f32; batch * chunk];
    let mut chunk_lens = vec![0usize; batch];
    for (lane, slot) in lanes.iter().enumerate().take(batch) {
        let Some(a) = slot else { continue };
        let Phase::Prefill { offset } = a.phase else { continue };
        let n = (a.prefill_ids.len() - offset).min(chunk);
        chunk_lens[lane] = n;
        for j in 0..n {
            tokens[lane * chunk + j] = a.prefill_ids[offset + j] as i32;
            positions[lane * chunk + j] = (offset + j) as i32;
            valid[lane * chunk + j] = 1.0;
        }
    }
    PrefillBatch {
        tokens,
        positions,
        valid,
        chunk_lens,
    }
}

/// Executor inputs for one decode step across all decoding lanes.
pub struct DecodeBatch {
    /// i32[B] current input token per lane (PAD on idle lanes).
    pub tokens: Vec<i32>,
    /// i32[B] position per lane.
    pub positions: Vec<i32>,
    /// Lanes actually decoding this step, ascending.
    pub lanes: Vec<usize>,
}

impl DecodeBatch {
    /// True when no lane is in decode phase.
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }
}

/// Pack the current token/position of every decoding lane.
pub fn assemble_decode(lanes: &[Option<ChainState>], batch: usize, pad: i32) -> DecodeBatch {
    let mut tokens = vec![pad; batch];
    let mut positions = vec![0i32; batch];
    let mut active = Vec::new();
    for (lane, slot) in lanes.iter().enumerate().take(batch) {
        let Some(a) = slot else { continue };
        if !matches!(a.phase, Phase::Decode) {
            continue;
        }
        tokens[lane] = a.cur_token as i32;
        positions[lane] = a.pos as i32;
        active.push(lane);
    }
    DecodeBatch {
        tokens,
        positions,
        lanes: active,
    }
}

/// Per-lane host work computed (possibly in parallel) after a decode
/// step: the lane's gathered output views, the policy's write actions,
/// and the sampled next token.
pub struct LaneStep {
    /// Lane index inside the executor batch.
    pub lane: usize,
    /// α per (layer, kv-head) — `[L*H]`.
    pub alpha: Vec<f32>,
    /// Attention mass per (layer, kv-head, slot) — `[L*H*S]`.
    pub attn: Vec<f32>,
    /// Self-attention mass per (layer, kv-head) — `[L*H]`.
    pub attn_self: Vec<f32>,
    /// Append/merge decision per (layer, kv-head).
    pub actions: Vec<WriteAction>,
    /// Token sampled from this step's logits.
    pub next_token: u32,
    /// Quest: pages selected by the executor this step (0 otherwise).
    pub quest_sel_pages: usize,
}

/// Below this many per-lane elements (attention view `L*H*S` — the
/// dominant copy), spawning a thread costs more than the work it
/// carries; such steps run inline even with `parallel` set.
const PARALLEL_MIN_ELEMS: usize = 8192;

/// Run the per-lane host work for every decoding lane. With
/// `parallel` set, more than one active lane, and per-lane views large
/// enough to be worth a thread spawn, each lane's work runs on its own
/// scoped thread; policy scoring and sampling only touch the lane's
/// own [`ChainState`] plus disjoint read-only slices of `out`, so the
/// result is identical to the sequential order (results are collected
/// in ascending lane order either way).
pub fn decode_host_work(
    lanes: &mut [Option<ChainState>],
    out: &DecodeOutputs,
    geom: Geometry,
    batch: usize,
    vocab: usize,
    quest: bool,
    parallel: bool,
    track_stats: bool,
) -> Vec<LaneStep> {
    let work: Vec<(usize, &mut ChainState)> = lanes
        .iter_mut()
        .enumerate()
        .take(batch)
        .filter_map(|(i, s)| s.as_mut().map(|c| (i, c)))
        .filter(|(_, c)| matches!(c.phase, Phase::Decode))
        .collect();
    let per_lane = geom.lh() * geom.slots;
    if !parallel || work.len() <= 1 || per_lane < PARALLEL_MIN_ELEMS {
        return work
            .into_iter()
            .map(|(lane, c)| {
                lane_step(lane, c, out, geom, batch, vocab, quest, track_stats)
            })
            .collect();
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = work
            .into_iter()
            .map(|(lane, c)| {
                s.spawn(move || {
                    lane_step(lane, c, out, geom, batch, vocab, quest, track_stats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("lane worker panicked"))
            .collect()
    })
}

fn lane_step(
    lane: usize,
    chain: &mut ChainState,
    out: &DecodeOutputs,
    geom: Geometry,
    batch: usize,
    vocab: usize,
    quest: bool,
    track_stats: bool,
) -> LaneStep {
    let (l, h, s) = (geom.layers, geom.kv_heads, geom.slots);
    let lh = l * h;
    // gathered in (layer, head) order, so the views build by append —
    // no zero-init pass over lh·s elements that the copy immediately
    // overwrites
    let mut alpha = Vec::with_capacity(lh);
    let mut attn = Vec::with_capacity(lh * s);
    let mut attn_self = Vec::with_capacity(lh);
    for li in 0..l {
        for hi in 0..h {
            let src = (li * batch + lane) * h + hi;
            alpha.push(out.alpha[src]);
            attn_self.push(out.attn_self[src]);
            attn.extend_from_slice(&out.attn[src * s..(src + 1) * s]);
        }
    }
    // fold this step's attention view into the chain's lane-local
    // budget-plan statistics (mass + entropy per (layer, head)) before
    // the policy consumes it. Only the adaptive allocator reads these,
    // so signal-free allocators skip the O(lh·slots) entropy pass —
    // the hot path stays as cheap as before the plan refactor.
    if track_stats {
        chain.attn_stats.observe_attn(l, h, s, &attn, &attn_self);
    }
    let mut actions = Vec::with_capacity(lh);
    chain.policy.write_actions(&alpha, l, h, &mut actions);
    let next_token = chain
        .sampler
        .sample(&out.logits[lane * vocab..(lane + 1) * vocab]);
    let quest_sel_pages = if quest {
        let pages = geom.pages();
        let mut sel = 0usize;
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * batch + lane) * h + hi) * pages;
                sel += out.qsel[base..base + pages]
                    .iter()
                    .filter(|&&x| x > 0.5)
                    .count();
            }
        }
        sel
    } else {
        0
    };
    LaneStep {
        lane,
        alpha,
        attn,
        attn_self,
        actions,
        next_token,
        quest_sel_pages,
    }
}
