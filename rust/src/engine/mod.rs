//! The serving engine (Layer 3 hot path).
//!
//! A fixed-width executor batch (B lanes) is continuously refilled from
//! a pending-chain queue (vLLM-style continuous batching). The
//! subsystem splits into:
//!
//! * [`scheduler`] — the control plane: admission queue, lane
//!   assignment, FCFS/shortest-first ordering, fork-sibling promotion,
//!   and recompute-style preemption under cache pressure;
//! * [`batch`] — step-batch assembly (one tick can carry a prefill
//!   chunk *and* a decode step across different lanes) and the
//!   scoped-thread fan-out of per-lane host work (policy scoring,
//!   sampling);
//! * `core` — the [`Engine`]: executors, weights, KV cache, and the
//!   tick loop; plus the dynamic-admission [`Session`] API the server
//!   uses to admit and retire concurrent requests mid-run;
//! * [`sim`] — the same control plane over a deterministic fake model
//!   (no PJRT artifacts needed): what cluster tests and the serve
//!   smoke benches spin up as engine replicas;
//! * [`timeflow`] — a discrete-event cluster *timing* simulator: the
//!   real router/steal decision cores under a virtual nanosecond
//!   clock, with per-stage costs priced from the App. G latency model
//!   (`bench_sim` gates its p50/p99/p999 TTFT + tokens/s in CI);
//! * [`slo`] — SLO tiers (TTFT + e2e deadline classes), EDF dispatch
//!   support, and KV-byte-budget admission control priced from the
//!   same cost model (compression widens the admissible set — the
//!   hyper-scaling dividend);
//! * [`workload`] — the seed-deterministic hyperscale load generator:
//!   arrival processes (uniform/Poisson/bursty/diurnal), request
//!   mixes (chat / long-context / width-W voting), zipf prompt reuse.
//!
//! Prefill runs in C-token chunks; parallel-scaling requests (W > 1)
//! prefill once and fork the prompt cache to sibling lanes
//! (copy-on-write prefix sharing). Every decode step drives the
//! compression policy and the §5.1 efficiency metrics (KV reads, peak
//! tokens).

pub mod batch;
pub mod scheduler;
pub mod sim;
pub mod slo;
pub mod timeflow;
pub mod workload;

mod core;
mod sampler;
mod sequence;
mod voting;

pub use self::core::{Engine, EngineStats, Session};
pub use sim::{SimEngine, SimEngineConfig};
pub use slo::{
    byte_capacity, AdmissionController, AdmissionDecision, SloPolicy, SloRequest, SloTier,
};
pub use timeflow::{
    generate_workload, simulate, simulate_requests, simulate_slo, Arrival, CostModel,
    ReplicaFailure, SimReport, SimRequest, Stage, StageSpan, TimeflowConfig, WorkloadSpec,
};
pub use workload::{
    generate_mixed_workload, slo_requests, ArrivalKind, RequestClass, WorkloadConfig,
    WorkloadRequest,
};
pub use sampler::Sampler;
pub use scheduler::{
    AdmissionPolicy, ChainState, CompletedRequest, PendingChain, Phase, ResumeState,
    Scheduler, SchedulerConfig,
};
pub use sequence::{
    ChainResult, ChainStats, FinishReason, GenRequest, GenResult, RequestTiming, SubmitSpec,
};
pub use voting::{aggregate, majority_vote, pass_at_all, VoteOutcome};
