//! The serving engine (Layer 3 hot path).
//!
//! A fixed-width executor batch (B lanes) is continuously refilled from
//! a pending-chain queue (vLLM-style continuous batching). Prefill runs
//! in C-token chunks; parallel-scaling requests (W > 1) prefill once and
//! fork the prompt cache to sibling lanes (copy-on-write prefix
//! sharing). Every decode step drives the compression policy and the
//! §5.1 efficiency metrics (KV reads, peak tokens).

mod core;
mod sampler;
mod sequence;
mod voting;

pub use core::{Engine, EngineStats};
pub use sampler::Sampler;
pub use sequence::{ChainStats, FinishReason, GenRequest, GenResult};
pub use voting::{aggregate, majority_vote, pass_at_all, VoteOutcome};
