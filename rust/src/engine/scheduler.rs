//! Continuous-batching scheduler: admission queue, lane assignment,
//! admission ordering (FCFS / shortest-first / EDF), and preemption on
//! cache pressure.
//!
//! The scheduler owns the *control plane* of the engine: which chain
//! occupies which executor lane, which pending chain is admitted next,
//! and when a running chain is preempted back into the queue. It knows
//! nothing about the executor, the KV cache payload, or tokenization —
//! the [`Engine`](super::Engine) (or a test harness) drives it through
//! a small imperative API:
//!
//! ```text
//! submit(req, ids) -> ticket          // enqueue W chains, FCFS by ticket
//! idle_lane() + next_admission()      // pick (lane, chain) pairs
//! install(lane, ChainState::new(..))  // place a chain on a lane
//! take(lane) + complete(..)           // retire a chain, maybe a request
//! maybe_preempt(live_fraction)        // recompute-style preemption
//! ```
//!
//! Decoupling the scheduler from the PJRT executor keeps every policy
//! decision (ordering, promotion of stranded fork-siblings, preemption)
//! testable with a simulated model — see `tests/property_coordinator.rs`.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use super::sampler::Sampler;
use super::sequence::{ChainResult, ChainStats, GenRequest, GenResult, RequestTiming};
use super::slo::SloTier;
use crate::compress::{AttnStats, Policy};

/// Which pending chain gets an idle lane first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Strict first-come-first-served by submission order. This is the
    /// fairness guarantee: no request starves, because admission order
    /// is exactly arrival order.
    #[default]
    Fcfs,
    /// Shortest-job-first by `max_len` (ties broken by ticket, i.e.
    /// submission order — queue *position* is not stable under work
    /// stealing or preemption re-queues). Improves mean latency under
    /// mixed workloads at the cost of delaying long requests; long
    /// requests cannot starve forever because new arrivals behind them
    /// are only preferred while strictly shorter.
    ShortestFirst,
    /// Earliest-deadline-first over the absolute e2e deadline stamped
    /// by [`Scheduler::assign_slo`] (ties broken by ticket, then chain
    /// index). Chains never stamped carry `u64::MAX` and sort last.
    Edf,
}

/// Scheduler tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Admission ordering for pending chains.
    pub admission: AdmissionPolicy,
    /// Live-slot fraction of the cache above which the scheduler
    /// preempts the youngest running chain whenever other chains are
    /// waiting and no lane is idle. Preempted chains are re-queued at
    /// the back (they yield their turn) and later resume by
    /// recomputation: the prompt plus everything generated so far is
    /// re-prefilled and decoding continues with the preserved sampler
    /// state. `None` disables preemption.
    pub preempt_watermark: Option<f64>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            admission: AdmissionPolicy::Fcfs,
            preempt_watermark: None,
        }
    }
}

/// Where a lane's chain is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// The first `offset` tokens of `prefill_ids` are in the cache.
    Prefill {
        /// Number of prompt tokens already written to the cache.
        offset: usize,
    },
    /// Prefill done; one new token per step.
    Decode,
}

/// Decode-time state preserved across a preemption, so a chain resumes
/// exactly where it stopped once it is re-admitted.
pub struct ResumeState {
    /// Sampler with its RNG stream advanced to the preemption point.
    pub sampler: Sampler,
    /// The sampled-but-not-yet-fed next input token.
    pub cur_token: u32,
    /// Tokens generated before the preemption.
    pub gen_ids: Vec<u32>,
    /// Per-chain statistics accumulated so far.
    pub stats: ChainStats,
}

/// A chain waiting in the admission queue.
pub struct PendingChain {
    /// Request ticket this chain belongs to (doubles as the fork group).
    pub ticket: u64,
    /// Index of this chain within its request (0..width).
    pub chain_idx: usize,
    /// Token sequence to prefill: BOS + prompt, and on resume also the
    /// tokens generated before preemption.
    pub prefill_ids: Arc<Vec<u32>>,
    /// Original prompt length in tokens (for stats; `prefill_ids` may
    /// be longer after a preemption).
    pub prompt_tokens: usize,
    /// Max total tokens for the chain (the L budget).
    pub max_len: usize,
    /// Sampling temperature.
    pub temperature: f64,
    /// Chain RNG seed (ignored when `resume` carries a sampler).
    pub seed: u64,
    /// Sibling that waits to fork from its group leader's prefill
    /// instead of prefilling by itself.
    pub wait_fork: bool,
    /// Present when the chain was preempted mid-decode.
    pub resume: Option<ResumeState>,
    /// When the chain entered the queue (first submission).
    pub enqueued: Instant,
    /// Prefix-cache hit: retained KV pages covering `prefix_tokens`
    /// leading prompt tokens. The chain holds one pool reference per
    /// page while queued (opaque handles — the engine owns the pool);
    /// the engine consumes them at install time (mapping the pages) or
    /// releases them when the chain forks off a leader instead.
    pub prefix_pages: Vec<u64>,
    /// Tokens covered by `prefix_pages` (prefill starts there).
    pub prefix_tokens: usize,
    /// SLO tier ([`Scheduler::assign_slo`]; `Standard` until stamped).
    pub tier: SloTier,
    /// Absolute e2e deadline — the EDF ordering key (`u64::MAX` until
    /// stamped; preserved across preemption re-queues).
    pub deadline_ns: u64,
}

/// A chain occupying an executor lane.
pub struct ChainState {
    /// Request ticket (also the fork group id).
    pub ticket: u64,
    /// Index of this chain within its request.
    pub chain_idx: usize,
    /// Token sequence being / already prefilled.
    pub prefill_ids: Arc<Vec<u32>>,
    /// Max total tokens (prompt + generation).
    pub max_len: usize,
    /// Compression policy instance (one per chain).
    pub policy: Box<dyn Policy>,
    /// Sampler (owns the chain's RNG stream).
    pub sampler: Sampler,
    /// Prefill/decode phase.
    pub phase: Phase,
    /// Next input token (valid in `Decode` phase).
    pub cur_token: u32,
    /// Tokens fed to the model so far.
    pub pos: usize,
    /// Generated tokens emitted so far.
    pub gen_ids: Vec<u32>,
    /// Per-chain efficiency statistics.
    pub stats: ChainStats,
    /// When the current residency on a lane started.
    pub started: Instant,
    /// Original seed (kept so a prefill-phase preemption can re-queue
    /// the chain without losing its identity).
    pub seed: u64,
    /// On resume: token to continue with instead of sampling from the
    /// prefill logits (that token was already sampled pre-preemption).
    pub resume_token: Option<u32>,
    /// Monotone admission sequence number; the maximum identifies the
    /// youngest chain (the preemption victim).
    pub admitted_seq: u64,
    /// Lane-local per-(layer, KV-head) attention statistics feeding
    /// the adaptive budget allocator. Accumulated from prefill α
    /// chunks and decode attention views; restarts empty on admission
    /// (a preempted chain re-accumulates after resume).
    pub attn_stats: AttnStats,
    /// SLO tier (carried from the pending chain; preemption never
    /// victimizes a stricter tier for a looser one).
    pub tier: SloTier,
    /// Absolute e2e deadline (carried from the pending chain).
    pub deadline_ns: u64,
}

impl ChainState {
    /// Build the lane state for a freshly admitted pending chain.
    pub fn new(p: PendingChain, policy: Box<dyn Policy>, top_k: usize) -> Self {
        let prompt_tokens = p.prompt_tokens;
        let (sampler, gen_ids, stats, resume_token) = match p.resume {
            Some(r) => (r.sampler, r.gen_ids, r.stats, Some(r.cur_token)),
            None => (
                Sampler::new(p.temperature, top_k, p.seed),
                Vec::new(),
                ChainStats {
                    prompt_tokens,
                    ..Default::default()
                },
                None,
            ),
        };
        Self {
            ticket: p.ticket,
            chain_idx: p.chain_idx,
            prefill_ids: p.prefill_ids,
            max_len: p.max_len,
            policy,
            sampler,
            phase: Phase::Prefill { offset: 0 },
            cur_token: 0,
            pos: 0,
            gen_ids,
            stats,
            started: Instant::now(),
            seed: p.seed,
            resume_token,
            admitted_seq: 0,
            attn_stats: AttnStats::new(),
            tier: p.tier,
            deadline_ns: p.deadline_ns,
        }
    }

    /// Build the lane state for a sibling forked from its group
    /// leader's completed prefill (copy-on-write prefix sharing). The
    /// sibling starts directly in `Decode` at the leader's position,
    /// reusing the leader's first sampled token; its own RNG stream is
    /// decorrelated with one warm-up draw.
    pub fn forked(
        p: PendingChain,
        policy: Box<dyn Policy>,
        top_k: usize,
        leader_token: u32,
        leader_pos: usize,
    ) -> Self {
        let mut sampler = Sampler::new(p.temperature, top_k, p.seed);
        sampler.sample(&[0.0]); // decorrelate RNG streams
        Self {
            ticket: p.ticket,
            chain_idx: p.chain_idx,
            prefill_ids: p.prefill_ids,
            max_len: p.max_len,
            policy,
            sampler,
            phase: Phase::Decode,
            cur_token: leader_token,
            pos: leader_pos,
            gen_ids: Vec::new(),
            stats: ChainStats {
                prompt_tokens: p.prompt_tokens,
                forked_prefill: true,
                ..Default::default()
            },
            started: Instant::now(),
            seed: p.seed,
            resume_token: None,
            admitted_seq: 0,
            attn_stats: AttnStats::new(),
            tier: p.tier,
            deadline_ns: p.deadline_ns,
        }
    }

    /// Tokens this chain may still generate before hitting `max_len`.
    pub fn remaining_budget(&self) -> usize {
        self.max_len.saturating_sub(self.pos)
    }
}

/// A fully answered request handed back by [`Scheduler::complete`].
pub struct CompletedRequest {
    /// Ticket returned by [`Scheduler::submit`].
    pub ticket: u64,
    /// All chains of the request, in chain order.
    pub result: GenResult,
    /// Queueing / first-token / end-to-end timing.
    pub timing: RequestTiming,
    /// SLO tier the request was served under, if one was assigned —
    /// the engine prices deadline misses and goodput against it.
    pub slo: Option<SloTier>,
}

/// Book-keeping for one in-flight request.
struct RequestState {
    chains: Vec<Option<ChainResult>>,
    remaining: usize,
    submitted: Instant,
    first_admit: Option<Instant>,
    first_token: Option<Instant>,
    slo: Option<SloTier>,
}

/// The continuous-batching scheduler (see module docs).
pub struct Scheduler {
    cfg: SchedulerConfig,
    lanes: Vec<Option<ChainState>>,
    pending: VecDeque<PendingChain>,
    requests: BTreeMap<u64, RequestState>,
    next_ticket: u64,
    admit_seq: u64,
    preemptions: u64,
}

impl Scheduler {
    /// A scheduler over `n_lanes` executor lanes.
    pub fn new(n_lanes: usize, cfg: SchedulerConfig) -> Self {
        Self {
            cfg,
            lanes: (0..n_lanes).map(|_| None).collect(),
            pending: VecDeque::new(),
            requests: BTreeMap::new(),
            next_ticket: 0,
            admit_seq: 0,
            preemptions: 0,
        }
    }

    /// Number of executor lanes managed.
    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Enqueue all `width` chains of a request; returns its ticket.
    /// Chain 0 is the fork-group leader; siblings wait to fork from its
    /// prefill (and are promoted to self-prefill if the leader is gone).
    /// A width of 0 is clamped to 1 — a request with no chains could
    /// never complete.
    pub fn submit(&mut self, req: &GenRequest, prompt_ids: Arc<Vec<u32>>) -> u64 {
        self.submit_with_prefix(req, prompt_ids, &[], 0)
    }

    /// Like [`Scheduler::submit`], carrying a prefix-cache hit: every
    /// chain of the request gets a copy of the page handles (the caller
    /// must hold one pool reference per page per chain) and will start
    /// prefill at `prefix_tokens` once installed.
    pub fn submit_with_prefix(
        &mut self,
        req: &GenRequest,
        prompt_ids: Arc<Vec<u32>>,
        prefix_pages: &[u64],
        prefix_tokens: usize,
    ) -> u64 {
        let width = req.width.max(1);
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let now = Instant::now();
        self.requests.insert(
            ticket,
            RequestState {
                chains: vec![None; width],
                remaining: width,
                submitted: now,
                first_admit: None,
                first_token: None,
                slo: None,
            },
        );
        for w in 0..width {
            self.pending.push_back(PendingChain {
                ticket,
                chain_idx: w,
                prefill_ids: prompt_ids.clone(),
                prompt_tokens: prompt_ids.len(),
                max_len: req.max_len,
                temperature: req.temperature,
                seed: req.seed.wrapping_add(w as u64),
                wait_fork: w > 0,
                resume: None,
                enqueued: now,
                prefix_pages: prefix_pages.to_vec(),
                prefix_tokens,
                tier: SloTier::Standard,
                deadline_ns: u64::MAX,
            });
        }
        ticket
    }

    /// Stamp a submitted request with its SLO tier and absolute e2e
    /// deadline (the [`AdmissionPolicy::Edf`] ordering key). Applies to
    /// every queued chain of the ticket and to chains already installed
    /// on lanes; both survive preemption re-queues. Call right after
    /// [`Scheduler::submit`] — requests never stamped serve as
    /// `Standard` with an unbounded deadline (they sort last under
    /// EDF).
    pub fn assign_slo(&mut self, ticket: u64, tier: SloTier, deadline_ns: u64) {
        if let Some(r) = self.requests.get_mut(&ticket) {
            r.slo = Some(tier);
        }
        for p in self.pending.iter_mut().filter(|p| p.ticket == ticket) {
            p.tier = tier;
            p.deadline_ns = deadline_ns;
        }
        for c in self.lanes.iter_mut().flatten().filter(|c| c.ticket == ticket) {
            c.tier = tier;
            c.deadline_ns = deadline_ns;
        }
    }

    /// Whether any chain is running or waiting.
    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.lanes.iter().any(Option::is_some)
    }

    /// Chains waiting in the admission queue.
    pub fn queue_depth(&self) -> usize {
        self.pending.len()
    }

    /// Lanes currently running a chain.
    pub fn active_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Total preemptions since construction.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Lowest-indexed idle lane, if any.
    pub fn idle_lane(&self) -> Option<usize> {
        self.lanes.iter().position(Option::is_none)
    }

    /// All lanes (read-only view for batch assembly).
    pub fn lanes(&self) -> &[Option<ChainState>] {
        &self.lanes
    }

    /// All lanes (mutable view for per-lane host work).
    pub fn lanes_mut(&mut self) -> &mut [Option<ChainState>] {
        &mut self.lanes
    }

    /// One lane's chain, if running.
    pub fn lane(&self, lane: usize) -> Option<&ChainState> {
        self.lanes[lane].as_ref()
    }

    /// One lane's chain, mutably.
    pub fn lane_mut(&mut self, lane: usize) -> Option<&mut ChainState> {
        self.lanes[lane].as_mut()
    }

    /// Pop the next chain to admit under the configured admission
    /// policy. Self-prefilling chains are preferred; a `wait_fork`
    /// sibling is only promoted to self-prefill when its leader is
    /// neither mid-prefill on a lane nor still waiting in the queue.
    pub fn next_admission(&mut self) -> Option<PendingChain> {
        let idx = match self.cfg.admission {
            AdmissionPolicy::Fcfs => self.pending.iter().position(|p| !p.wait_fork),
            // ties break on (ticket, chain_idx), never on queue
            // position: position is permuted by steals and preemption
            // re-queues, so two same-seed runs would diverge on it.
            AdmissionPolicy::ShortestFirst => self
                .pending
                .iter()
                .enumerate()
                .filter(|(_, p)| !p.wait_fork)
                .min_by_key(|(_, p)| (p.max_len, p.ticket, p.chain_idx))
                .map(|(i, _)| i),
            AdmissionPolicy::Edf => self
                .pending
                .iter()
                .enumerate()
                .filter(|(_, p)| !p.wait_fork)
                .min_by_key(|(_, p)| (p.deadline_ns, p.ticket, p.chain_idx))
                .map(|(i, _)| i),
        };
        let idx = idx.or_else(|| {
            let blocked = self.blocked_fork_tickets();
            self.pending
                .iter()
                .position(|p| !blocked.contains(&p.ticket))
        })?;
        let p = self.pending.remove(idx).unwrap();
        if let Some(r) = self.requests.get_mut(&p.ticket) {
            if r.first_admit.is_none() {
                r.first_admit = Some(Instant::now());
            }
        }
        Some(p)
    }

    /// Tickets whose `wait_fork` siblings must keep waiting: the group
    /// leader is either mid-prefill on a lane (a fork is coming) or
    /// still in the queue as a self-prefilling chain. One O(pending +
    /// lanes) pre-pass so admission scans stay linear in queue depth.
    fn blocked_fork_tickets(&self) -> BTreeSet<u64> {
        let mut blocked: BTreeSet<u64> = self
            .pending
            .iter()
            .filter(|q| !q.wait_fork)
            .map(|q| q.ticket)
            .collect();
        blocked.extend(
            self.lanes
                .iter()
                .flatten()
                .filter(|a| matches!(a.phase, Phase::Prefill { .. }))
                .map(|a| a.ticket),
        );
        blocked
    }

    /// Place a chain on an idle lane.
    ///
    /// # Panics
    /// Panics if the lane is already occupied.
    pub fn install(&mut self, lane: usize, mut chain: ChainState) {
        assert!(self.lanes[lane].is_none(), "lane {lane} is occupied");
        self.admit_seq += 1;
        chain.admitted_seq = self.admit_seq;
        chain.started = Instant::now();
        self.lanes[lane] = Some(chain);
    }

    /// Pop a queued fork-sibling of `ticket`, if one is waiting.
    pub fn take_fork_sibling(&mut self, ticket: u64) -> Option<PendingChain> {
        let idx = self
            .pending
            .iter()
            .position(|p| p.ticket == ticket && p.wait_fork)?;
        let p = self.pending.remove(idx).unwrap();
        if let Some(r) = self.requests.get_mut(&ticket) {
            if r.first_admit.is_none() {
                r.first_admit = Some(Instant::now());
            }
        }
        Some(p)
    }

    /// Tickets that can be handed to another scheduler wholesale,
    /// youngest (most recently submitted) first: every chain of the
    /// request still waits in the queue — none installed on a lane,
    /// none completed, none carrying preemption resume state. Only
    /// such *fresh* requests are migration-safe: they own no lane
    /// cache state and no progress beyond the prefix-page references
    /// the engine released on drain.
    fn stealable_tickets(&self) -> Vec<u64> {
        // steady-state fast path: the serving loop probes this after
        // every tick, and with nothing queued there is nothing to
        // steal — skip the allocating scans entirely.
        if self.pending.is_empty() {
            return Vec::new();
        }
        let on_lanes: BTreeSet<u64> =
            self.lanes.iter().flatten().map(|c| c.ticket).collect();
        let mut pend: BTreeMap<u64, (usize, bool)> = BTreeMap::new();
        let mut order: Vec<u64> = Vec::new();
        for p in &self.pending {
            let e = pend.entry(p.ticket).or_insert((0, false));
            if e.0 == 0 {
                order.push(p.ticket);
            }
            e.0 += 1;
            e.1 |= p.resume.is_some();
        }
        order.retain(|t| {
            let (n, resumed) = pend[t];
            !resumed
                && !on_lanes.contains(t)
                && self
                    .requests
                    .get(t)
                    .map(|r| r.remaining == r.chains.len() && n == r.chains.len())
                    .unwrap_or(false)
        });
        order.reverse(); // youngest first: longest expected wait
        order
    }

    /// Number of whole requests currently eligible for
    /// [`Scheduler::drain_queued`] — the router's steal-planning probe.
    pub fn stealable_requests(&self) -> usize {
        self.stealable_tickets().len()
    }

    /// Hand over up to `max_requests` *queued* requests (eligibility
    /// as in `stealable_tickets`: installed, partially run, or
    /// resumed chains are never migrated). The
    /// youngest queued requests go first — they face the longest wait
    /// here and the imminent admissions keep their FCFS turn. Each
    /// entry is the ticket plus its chains in chain order; the request
    /// book-keeping is dropped, so the caller re-submits wholesale on
    /// the destination scheduler (timings restart there).
    pub fn drain_queued(&mut self, max_requests: usize) -> Vec<(u64, Vec<PendingChain>)> {
        let victims: Vec<u64> = self
            .stealable_tickets()
            .into_iter()
            .take(max_requests)
            .collect();
        let mut out = Vec::with_capacity(victims.len());
        for t in victims {
            let mut chains: Vec<PendingChain> = Vec::new();
            let mut i = 0;
            while i < self.pending.len() {
                if self.pending[i].ticket == t {
                    chains.push(self.pending.remove(i).unwrap());
                } else {
                    i += 1;
                }
            }
            chains.sort_by_key(|c| c.chain_idx);
            self.requests.remove(&t);
            out.push((t, chains));
        }
        out
    }

    /// Record the request's first sampled token (TTFT), once. Returns
    /// whether this call was the one that recorded it — the engine's
    /// trace layer emits its `first_token` event exactly then (a
    /// resumed chain re-completing prefill is not a first token).
    pub fn note_first_token(&mut self, ticket: u64) -> bool {
        if let Some(r) = self.requests.get_mut(&ticket) {
            if r.first_token.is_none() {
                r.first_token = Some(Instant::now());
                return true;
            }
        }
        false
    }

    /// Remove and return the chain running on `lane`.
    pub fn take(&mut self, lane: usize) -> Option<ChainState> {
        self.lanes[lane].take()
    }

    /// Record a finished chain; returns the whole request when its last
    /// chain completes.
    pub fn complete(
        &mut self,
        ticket: u64,
        chain_idx: usize,
        result: ChainResult,
    ) -> Option<CompletedRequest> {
        let r = self.requests.get_mut(&ticket)?;
        if r.chains[chain_idx].is_none() {
            r.remaining -= 1;
        }
        r.chains[chain_idx] = Some(result);
        if r.remaining > 0 {
            return None;
        }
        let r = self.requests.remove(&ticket)?;
        let chains: Vec<ChainResult> = r.chains.into_iter().map(|c| c.unwrap()).collect();
        let gen_tokens = chains.iter().map(|c| c.stats.gen_tokens).sum();
        let e2e_ms = r.submitted.elapsed().as_secs_f64() * 1e3;
        let ms = |t: Option<Instant>| {
            t.map(|t| t.duration_since(r.submitted).as_secs_f64() * 1e3)
                .unwrap_or(0.0)
        };
        Some(CompletedRequest {
            ticket,
            result: GenResult { chains },
            timing: RequestTiming {
                queue_ms: ms(r.first_admit),
                ttft_ms: ms(r.first_token),
                e2e_ms,
                gen_tokens,
            },
            slo: r.slo,
        })
    }

    /// Preempt under cache pressure: when the live-slot fraction
    /// exceeds the configured watermark, chains are waiting, and no
    /// lane is idle, the youngest running chain is pushed back into the
    /// queue (at the back, yielding its turn) with its decode state
    /// preserved for recompute-resume. Returns the freed lane so the
    /// caller can recycle its cache slots. At most one preemption per
    /// call keeps the scheduler's behaviour gradual.
    pub fn maybe_preempt(&mut self, live_fraction: f64) -> Option<usize> {
        self.maybe_preempt_traced(live_fraction).map(|(lane, _)| lane)
    }

    /// Like [`Scheduler::maybe_preempt`], additionally returning the
    /// preempted chain's ticket so the engine can stamp a `preempt`
    /// trace event against the right request.
    pub fn maybe_preempt_traced(&mut self, live_fraction: f64) -> Option<(usize, u64)> {
        let watermark = self.cfg.preempt_watermark?;
        if live_fraction < watermark
            || self.pending.is_empty()
            || self.idle_lane().is_some()
        {
            return None;
        }
        // SLO invariant: never preempt a stricter tier for a looser
        // one — the victim pool is restricted to lanes serving a tier
        // no stricter than the best (lowest) tier waiting in the queue.
        let beneficiary_tier = self.best_pending_tier()?;
        let lane = self.preempt_candidate_for(beneficiary_tier)?;
        let victim = self.lanes[lane].as_ref()?;
        let (victim_max_len, victim_deadline, ticket) =
            (victim.max_len, victim.deadline_ns, victim.ticket);
        if !self.admission_would_benefit(victim_max_len, victim_deadline, ticket) {
            return None;
        }
        self.preempt(lane);
        Some((lane, ticket))
    }

    /// Strictest (lowest) tier among chains that could actually be
    /// admitted right now — the tier preemption would benefit.
    fn best_pending_tier(&self) -> Option<SloTier> {
        let blocked = self.blocked_fork_tickets();
        self.pending
            .iter()
            .filter(|p| !p.wait_fork || !blocked.contains(&p.ticket))
            .map(|p| p.tier)
            .min()
    }

    /// Whether some currently waiting chain would actually be admitted
    /// ahead of the preemption victim once it is re-queued at the back.
    /// Without this check, preempting could free a lane only for the
    /// follow-up admission to reinstall the victim itself — a pure
    /// recompute of its KV cache with zero capacity gained.
    fn admission_would_benefit(
        &self,
        victim_max_len: usize,
        victim_deadline_ns: u64,
        victim_ticket: u64,
    ) -> bool {
        let blocked = self.blocked_fork_tickets();
        self.pending.iter().any(|p| {
            let admissible = !p.wait_fork || !blocked.contains(&p.ticket);
            admissible
                && match self.cfg.admission {
                    // FCFS: anything already queued sits ahead of the
                    // re-queued victim.
                    AdmissionPolicy::Fcfs => true,
                    // shortest-first: the waiting chain wins only if it
                    // is no longer than the victim (ties break FCFS,
                    // and the victim re-enters at the back).
                    AdmissionPolicy::ShortestFirst => p.max_len <= victim_max_len,
                    // EDF: the victim keeps its deadline and ticket in
                    // the queue, so the waiting chain wins only if it
                    // sorts strictly ahead on the same key.
                    AdmissionPolicy::Edf => {
                        (p.deadline_ns, p.ticket) < (victim_deadline_ns, victim_ticket)
                    }
                }
        })
    }

    /// The preferred preemption victim: the youngest chain in decode
    /// phase, falling back to the youngest prefilling chain.
    pub fn preempt_candidate(&self) -> Option<usize> {
        // unfiltered: every tier is `>= Interactive`
        self.preempt_candidate_for(SloTier::Interactive)
    }

    /// [`Scheduler::preempt_candidate`] restricted to lanes whose tier
    /// is no stricter than `beneficiary_tier` — the cross-tier
    /// preemption-inversion guard (tests/slo_admission.rs).
    fn preempt_candidate_for(&self, beneficiary_tier: SloTier) -> Option<usize> {
        let youngest = |decode: bool| {
            self.lanes
                .iter()
                .enumerate()
                .filter_map(|(i, l)| l.as_ref().map(|c| (i, c)))
                .filter(|(_, c)| c.tier >= beneficiary_tier)
                .filter(|(_, c)| matches!(c.phase, Phase::Decode) == decode)
                .max_by_key(|(_, c)| c.admitted_seq)
                .map(|(i, _)| i)
        };
        youngest(true).or_else(|| youngest(false))
    }

    /// Move the chain on `lane` back into the pending queue. Decode
    /// progress is preserved in a [`ResumeState`]; a chain still in its
    /// first prefill is simply re-queued from scratch. The freed lane's
    /// cache must be recycled by the caller.
    pub fn preempt(&mut self, lane: usize) {
        let Some(mut chain) = self.lanes[lane].take() else {
            return;
        };
        chain.stats.wall_s += chain.started.elapsed().as_secs_f64();
        // the token the chain will feed next, if it already sampled one:
        // mid-decode that is `cur_token`; mid-*re*-prefill (a resumed
        // chain preempted again) it is the preserved `resume_token`; a
        // chain in its first prefill has none and restarts cleanly.
        let next_token = match chain.phase {
            Phase::Decode => Some(chain.cur_token),
            Phase::Prefill { .. } => chain.resume_token,
        };
        let pending = match next_token {
            Some(cur) => {
                // the sequence fed (or being re-fed) so far is prompt +
                // generated tokens; re-prefilling it reproduces the
                // decode-time cache shape up to policy recompute
                // differences. Rebuild from the original prompt prefix
                // — after an earlier resume, `prefill_ids` already
                // contains generated tokens, and `gen_ids` always holds
                // all of them.
                let mut ids: Vec<u32> =
                    chain.prefill_ids[..chain.stats.prompt_tokens].to_vec();
                ids.extend_from_slice(&chain.gen_ids);
                PendingChain {
                    ticket: chain.ticket,
                    chain_idx: chain.chain_idx,
                    prefill_ids: Arc::new(ids),
                    prompt_tokens: chain.stats.prompt_tokens,
                    max_len: chain.max_len,
                    temperature: chain.sampler.temperature,
                    seed: chain.seed,
                    wait_fork: false,
                    resume: Some(ResumeState {
                        sampler: chain.sampler,
                        cur_token: cur,
                        gen_ids: chain.gen_ids,
                        stats: chain.stats,
                    }),
                    enqueued: Instant::now(),
                    prefix_pages: Vec::new(),
                    prefix_tokens: 0,
                    tier: chain.tier,
                    deadline_ns: chain.deadline_ns,
                }
            }
            None => PendingChain {
                ticket: chain.ticket,
                chain_idx: chain.chain_idx,
                prefill_ids: chain.prefill_ids,
                prompt_tokens: chain.stats.prompt_tokens,
                max_len: chain.max_len,
                temperature: chain.sampler.temperature,
                seed: chain.seed,
                wait_fork: false,
                resume: None,
                enqueued: Instant::now(),
                prefix_pages: Vec::new(),
                prefix_tokens: 0,
                tier: chain.tier,
                deadline_ns: chain.deadline_ns,
            },
        };
        self.pending.push_back(pending);
        self.preemptions += 1;
    }
}
