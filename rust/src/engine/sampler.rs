//! Token sampling: greedy / temperature / top-k, host-side.

use crate::util::SplitMix64;

/// Sampler configuration + RNG state (one per chain).
pub struct Sampler {
    pub temperature: f64,
    pub top_k: usize,
    rng: SplitMix64,
}

impl Sampler {
    pub fn new(temperature: f64, top_k: usize, seed: u64) -> Self {
        Self {
            temperature,
            top_k,
            rng: SplitMix64::new(seed),
        }
    }

    /// Sample a token id from unnormalized logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.temperature <= 0.0 {
            return argmax(logits) as u32;
        }
        let inv_t = 1.0 / self.temperature;
        // optional top-k truncation
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.sort_unstable_by(|&a, &b| {
                logits[b].partial_cmp(&logits[a]).unwrap()
            });
            idx.truncate(self.top_k);
        }
        let max = idx
            .iter()
            .map(|&i| logits[i] as f64)
            .fold(f64::NEG_INFINITY, f64::max);
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| ((logits[i] as f64 - max) * inv_t).exp())
            .collect();
        idx[self.rng.weighted(&weights)] as u32
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_takes_argmax() {
        let mut s = Sampler::new(0.0, 0, 1);
        assert_eq!(s.sample(&[0.1, 2.0, -1.0]), 1);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut s = Sampler::new(1.0, 0, 7);
        let logits = [5.0f32, 0.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if s.sample(&logits) == 0 {
                hits += 1;
            }
        }
        assert!(hits > 150, "high-logit token should dominate: {hits}");
    }

    #[test]
    fn top_k_excludes_tail() {
        let mut s = Sampler::new(1.0, 2, 3);
        let logits = [3.0f32, 2.9, -10.0, -10.0];
        for _ in 0..100 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let logits = [1.0f32, 1.1, 0.9, 1.05];
        let run = |seed| {
            let mut s = Sampler::new(0.8, 0, seed);
            (0..20).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
