//! Request/result types and per-chain statistics.

use super::slo::SloTier;

/// Why a chain stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted `<eos>` or a terminating newline.
    Stop,
    /// Hit the L budget (max total tokens).
    Length,
    /// Ran out of physical cache slots (vanilla at L > S only).
    Overflow,
}

/// A generation request: one prompt, W parallel chains.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    /// Parallel chains (parallel scaling width W).
    pub width: usize,
    /// Max total tokens per chain (prompt + generation) — the L budget.
    pub max_len: usize,
    /// Sampling temperature (chains > 1 need > 0 to differ).
    pub temperature: f64,
    /// Base RNG seed; chain i uses seed + i.
    pub seed: u64,
}

impl GenRequest {
    pub fn new(prompt: impl Into<String>) -> Self {
        Self {
            prompt: prompt.into(),
            width: 1,
            max_len: 160,
            temperature: 0.7,
            seed: 0,
        }
    }
}

/// One typed submission: the generation request plus the serving
/// metadata that used to ride in separate `submit_traced` /
/// `assign_slo` calls. This is the single argument of the serving
/// `Backend::submit` entrypoint (and of `Engine::submit_spec` /
/// `SimEngine::submit_spec`), so a request's identity, tracing key,
/// and deadline class travel together and can never be half-applied.
#[derive(Clone, Debug)]
pub struct SubmitSpec {
    /// The generation work itself.
    pub request: GenRequest,
    /// Client-visible request id for the flight recorder; `None` keys
    /// trace events by the engine-local ticket instead.
    pub trace_id: Option<u64>,
    /// SLO tier to stamp on the ticket at submission (EDF ordering,
    /// deadline accounting); `None` skips deadline accounting.
    pub slo: Option<SloTier>,
}

impl SubmitSpec {
    /// A plain untraced, untiered submission of `request`.
    pub fn new(request: GenRequest) -> Self {
        Self {
            request,
            trace_id: None,
            slo: None,
        }
    }

    /// Key this request's trace events by a client-visible id.
    pub fn traced(mut self, trace_id: u64) -> Self {
        self.trace_id = Some(trace_id);
        self
    }

    /// Stamp the request with an SLO tier at submission.
    pub fn with_slo(mut self, tier: SloTier) -> Self {
        self.slo = Some(tier);
        self
    }
}

/// Per-chain efficiency statistics (paper §5.1 metrics).
#[derive(Clone, Debug, Default)]
pub struct ChainStats {
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// KV items attended across decode steps, token units
    /// (mean over layer×head, +1 self per step). Quest: distinct pages
    /// × page size + page-metadata reads.
    pub decode_reads: f64,
    /// KV items attended during prefill chunks.
    pub prefill_reads: f64,
    /// Peak live tokens in memory (token units; + Quest metadata).
    pub peak_tokens: f64,
    /// Live tokens at completion.
    pub final_tokens: f64,
    /// Eviction decisions (α>0.5 count over L×H) per position —
    /// drives Fig. 6-left (CR vs generated length).
    pub evictions_per_pos: Vec<u16>,
    /// (live_final, tokens_seen) per (layer, kv-head) — Fig. 6-right.
    pub retained_per_lh: Vec<(usize, usize)>,
    /// Wall-clock time this chain was active, seconds.
    pub wall_s: f64,
    /// Whether the prompt cache was forked from a sibling chain.
    pub forked_prefill: bool,
    /// Prompt tokens restored from the radix prefix cache instead of
    /// being prefilled (0 when the chain prefilled from scratch).
    pub prefix_hit_tokens: usize,
}

impl ChainStats {
    /// Total reads (prefill + decode) — the x-axis of Fig. 3.
    pub fn total_reads(&self) -> f64 {
        self.decode_reads + self.prefill_reads
    }

    /// Achieved compression ratio: tokens seen / live entries kept,
    /// averaged over heads (compare Fig. 6).
    pub fn achieved_cr(&self) -> f64 {
        let (mut live, mut seen) = (0usize, 0usize);
        for &(l, s) in &self.retained_per_lh {
            live += l;
            seen += s;
        }
        if live == 0 {
            1.0
        } else {
            seen as f64 / live as f64
        }
    }
}

/// One finished chain.
#[derive(Clone, Debug)]
pub struct ChainResult {
    pub text: String,
    pub finish: FinishReason,
    pub stats: ChainStats,
}

/// Per-request serving timings, measured by the scheduler from
/// submission to completion.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RequestTiming {
    /// Milliseconds between submission and the first chain's admission
    /// to a lane (pure queueing delay).
    pub queue_ms: f64,
    /// Milliseconds between submission and the request's first sampled
    /// token (time-to-first-token).
    pub ttft_ms: f64,
    /// Milliseconds between submission and the last chain finishing.
    pub e2e_ms: f64,
    /// Tokens generated across all chains of the request.
    pub gen_tokens: usize,
}

impl RequestTiming {
    /// Request-level generation throughput (tokens per wall second).
    pub fn tokens_per_s(&self) -> f64 {
        if self.e2e_ms <= 0.0 {
            0.0
        } else {
            self.gen_tokens as f64 / (self.e2e_ms / 1e3)
        }
    }
}

/// All chains of a request.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub chains: Vec<ChainResult>,
}

impl GenResult {
    /// Sum of reads across chains (the request's compute budget use).
    pub fn total_reads(&self) -> f64 {
        self.chains.iter().map(|c| c.stats.total_reads()).sum()
    }

    /// Peak memory across concurrent chains (sum — chains run in
    /// parallel lanes, so their peaks add).
    pub fn total_peak_tokens(&self) -> f64 {
        self.chains.iter().map(|c| c.stats.peak_tokens).sum()
    }

    pub fn texts(&self) -> Vec<&str> {
        self.chains.iter().map(|c| c.text.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn achieved_cr_from_retention() {
        let stats = ChainStats {
            retained_per_lh: vec![(25, 100), (25, 100)],
            ..Default::default()
        };
        assert!((stats.achieved_cr() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn result_totals_sum_chains() {
        let mk = |reads: f64, peak: f64| ChainResult {
            text: String::new(),
            finish: FinishReason::Stop,
            stats: ChainStats {
                decode_reads: reads,
                peak_tokens: peak,
                ..Default::default()
            },
        };
        let r = GenResult {
            chains: vec![mk(10.0, 5.0), mk(20.0, 7.0)],
        };
        assert_eq!(r.total_reads(), 30.0);
        assert_eq!(r.total_peak_tokens(), 12.0);
    }
}
