//! Simulated engine: the real control plane (scheduler, KV cache, COW
//! pool, radix prefix index) with the PJRT executor replaced by a
//! deterministic fake model.
//!
//! [`SimEngine`] exists because the real [`Engine`](super::Engine)
//! cannot be constructed without AOT-compiled artifacts — which CI and
//! the offline dev container don't have — yet the cluster/router
//! subsystem, the serve smoke benches, and the router invariant tests
//! all need *many* engine replicas they can drive end-to-end. The sim
//! keeps everything that matters for those surfaces real:
//!
//! * the actual [`Scheduler`] (admission ordering, fork promotion,
//!   work-steal draining, timings) — the same code path `Engine::tick`
//!   drives;
//! * an actual [`CacheStore`] — prefills and decodes write real KV
//!   payloads, width-W requests fork via `fork_lane_cow`, retired
//!   prompts retain clean pages, and the store's `KvDtype` governs
//!   pool payloads (so the `KV_DTYPE=q8` CI leg exercises quantized
//!   publish/restore through this path too);
//! * an actual [`RadixPrefixIndex`] — repeated prompts are admitted at
//!   the divergence point and report `prefix_hit_tokens`, exactly like
//!   the real engine.
//!
//! Only the model is fake: logits are a pure function of the position
//! (`sim_logits`), so a chain's token stream depends solely on its
//! seed, prompt length, and budget — **never** on lane assignment,
//! admission order, or which replica ran it. That schedule-independence
//! is what makes cluster-of-1 bit-exactness testable at all. Token
//! `SIM_EOS` (0) terminates a chain, standing in for `<eos>`.
//!
//! Costs are real wall-clock work (cache writes per token, optionally
//! inflated by [`SimEngineConfig::work_per_token`]), so prefill skipped
//! via prefix hits translates into measurably higher tokens/s — the
//! quantity the serve smoke bench gates on.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::scheduler::{ChainState, CompletedRequest, Phase, Scheduler, SchedulerConfig};
use super::sequence::{ChainResult, FinishReason, GenRequest, SubmitSpec};
use super::slo::SloTier;
use super::EngineStats;
use crate::compress::{
    build_allocator, build_policy, AllocatorKind, BudgetAllocator, BudgetPlan, Policy,
    PolicyKind,
};
use crate::kvcache::{CacheStore, ColdTier, Geometry, KvDtype, PageId, RadixPrefixIndex};
use crate::metrics::Registry;
use crate::trace::{Stamped, TraceEvent, Tracer};
use crate::util::SplitMix64;

/// Token id that terminates a simulated chain (stands in for `<eos>`).
pub const SIM_EOS: u32 = 0;
/// Sim BOS marker (never produced by sampling: sampled ids are < 16).
const SIM_BOS: u32 = 1;
/// Prompt bytes are offset here so they never collide with sampled ids.
const SIM_BYTE_BASE: u32 = 16;

/// Deterministic fake logits: a pure function of the position over a
/// 16-token vocabulary (shared with `tests/property_coordinator.rs`'s
/// inline twin — the contract is the *purity*, not the values).
pub fn sim_logits(pos: usize) -> Vec<f32> {
    let mut r = SplitMix64::new(0x51E0_C0DE ^ (pos as u64).wrapping_mul(0x9E37));
    (0..16).map(|_| r.f64() as f32).collect()
}

/// Shape and behaviour knobs of a [`SimEngine`].
#[derive(Clone, Copy, Debug)]
pub struct SimEngineConfig {
    /// Executor lanes (the real engine's `batch`).
    pub lanes: usize,
    /// Cache geometry (slots must fit the largest `max_len`).
    pub geom: Geometry,
    /// Prefill tokens consumed per lane per tick (the chunk size).
    pub chunk: usize,
    /// Retain clean prompt pages and admit repeats at the divergence
    /// point (mirrors `EngineConfig::prefix_cache`).
    pub prefix_cache: bool,
    /// Retained-page budget of the prefix index.
    pub prefix_cache_pages: usize,
    /// Cold-tier RAM budget in bytes for demoted prefix pages; 0
    /// disables demotion (mirrors `EngineConfig::cold_tier_bytes`).
    /// Spill-to-disk is off by default — see
    /// [`SimEngine::set_spill_dir`].
    pub cold_tier_bytes: usize,
    /// Storage dtype demoted blocks are re-encoded into (mirrors
    /// `EngineConfig::cold_dtype`).
    pub cold_dtype: KvDtype,
    /// Pool payload precision (mirrors `EngineConfig::kv_dtype`).
    pub kv_dtype: KvDtype,
    /// Budget allocator shaping per-chain plans (mirrors
    /// `EngineConfig::allocator`). The sim's vanilla policy is
    /// unbudgeted, so plans only drive the `kv.plan_*` gauges — the
    /// same summaries the real engine reports per replica.
    pub allocator: AllocatorKind,
    /// Extra deterministic host work per written token (arithmetic
    /// iterations), emulating executor cost so serving benches see
    /// realistic prefill/decode ratios. 0 = cache writes only.
    pub work_per_token: usize,
    /// Flight-recorder ring capacity in events (mirrors
    /// `EngineConfig::trace_events`). 0 installs the no-op sink. Unlike
    /// the real engine the sim stamps events with its *logical tick
    /// counter* (1 tick ≡ 1 ms), so same-seed traces are bit-identical
    /// across runs and machines — the property `tests/observability.rs`
    /// asserts.
    pub trace_events: usize,
}

impl Default for SimEngineConfig {
    fn default() -> Self {
        Self {
            lanes: 4,
            geom: Geometry {
                layers: 2,
                kv_heads: 2,
                slots: 320,
                head_dim: 16,
                page_size: 16,
            },
            chunk: 32,
            prefix_cache: true,
            prefix_cache_pages: 1024,
            cold_tier_bytes: 0,
            cold_dtype: KvDtype::Q4,
            kv_dtype: KvDtype::F32,
            allocator: AllocatorKind::Uniform,
            work_per_token: 0,
            trace_events: 0,
        }
    }
}

/// The simulated engine (see module docs). API mirrors the dynamic-
/// admission surface of [`Engine`](super::Engine): `submit` / `tick` /
/// `is_idle` / `drain_queued`, so the cluster drives either through
/// one backend trait.
pub struct SimEngine {
    /// Configuration this sim was built with.
    pub cfg: SimEngineConfig,
    /// Serving metrics registry (same metric names as the engine).
    pub metrics: Registry,
    sched: Scheduler,
    cache: CacheStore,
    prefix_index: RadixPrefixIndex,
    /// Cold tier for demoted prefix pages (mirrors `Engine::cold`).
    cold: ColdTier,
    /// Built once from `cfg.allocator` (plans are recomputed per tick
    /// for the gauges, but the strategy object is not).
    allocator: Box<dyn BudgetAllocator>,
    stats: EngineStats,
    spin: f32,
    tracer: Tracer,
    /// ticket → client-visible request id (see `Engine::trace_ids`).
    trace_ids: BTreeMap<u64, u64>,
    tick_read_tokens: f64,
}

impl SimEngine {
    /// Build a sim engine with default FCFS scheduling.
    pub fn new(cfg: SimEngineConfig) -> Self {
        let mut cache = CacheStore::with_dtype(cfg.geom, cfg.lanes, cfg.kv_dtype);
        let tracer = Tracer::ring(cfg.trace_events);
        cache.set_event_tracking(tracer.enabled());
        Self {
            sched: Scheduler::new(cfg.lanes, SchedulerConfig::default()),
            cache,
            prefix_index: RadixPrefixIndex::new(cfg.geom.page_size),
            cold: ColdTier::new(
                cfg.cold_tier_bytes,
                cfg.cold_dtype,
                None,
                cfg.geom.head_dim,
            ),
            allocator: build_allocator(cfg.allocator),
            metrics: Registry::default(),
            stats: EngineStats::default(),
            cfg,
            spin: 0.0,
            tracer,
            trace_ids: BTreeMap::new(),
            tick_read_tokens: 0.0,
        }
    }

    /// Route cold-tier overflow to spill files under `dir` instead of
    /// evicting it. Call right after construction (rebuilds the tier;
    /// any blocks already demoted are dropped, their spill files
    /// deleted).
    pub fn set_spill_dir(&mut self, dir: PathBuf) {
        self.cold = ColdTier::new(
            self.cfg.cold_tier_bytes,
            self.cfg.cold_dtype,
            Some(dir),
            self.cfg.geom.head_dim,
        );
    }

    // ---- observability (see docs/OBSERVABILITY.md) ------------------

    /// Sim-time stamp: the logical tick counter, scaled so one tick
    /// reads as 1 ms in Perfetto. Pure function of the seed — never
    /// wall clock.
    fn now_ns(&self) -> u64 {
        self.stats.ticks * 1_000_000
    }

    /// Client-visible id for a ticket (falls back to the ticket).
    fn trace_req(&self, ticket: u64) -> u64 {
        self.trace_ids.get(&ticket).copied().unwrap_or(ticket)
    }

    /// The flight recorder (no-op sink unless `cfg.trace_events > 0`).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Recorded events for one client-visible request id, in order.
    pub fn trace_events_for(&self, req: u64) -> Vec<Stamped> {
        self.tracer.events_for(req)
    }

    /// Full-model KV bytes read per attended token (see
    /// `Engine::kv_bytes_per_token`).
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.cache.payload_bytes_per_token() * self.cfg.geom.lh() as f64
    }

    /// Accumulated engine statistics.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Chains waiting for a lane.
    pub fn queue_depth(&self) -> usize {
        self.sched.queue_depth()
    }

    /// Lanes currently running a chain.
    pub fn active_lanes(&self) -> usize {
        self.sched.active_lanes()
    }

    /// Lane count (the admission capacity per tick).
    pub fn n_lanes(&self) -> usize {
        self.sched.n_lanes()
    }

    /// Whole queued requests eligible for steal handoff.
    pub fn stealable_requests(&self) -> usize {
        self.sched.stealable_requests()
    }

    /// Whether nothing is running or queued.
    pub fn is_idle(&self) -> bool {
        !self.sched.has_work()
    }

    /// Sim tokenizer: BOS + one id per prompt byte.
    fn encode(prompt: &str) -> Vec<u32> {
        let mut ids = Vec::with_capacity(prompt.len() + 1);
        ids.push(SIM_BOS);
        ids.extend(prompt.as_bytes().iter().map(|&b| SIM_BYTE_BASE + b as u32));
        ids
    }

    /// Tokenize, validate, and enqueue one request (mirrors
    /// `Engine::submit`, including prefix-cache admission).
    pub fn submit(&mut self, req: &GenRequest) -> Result<u64> {
        self.submit_traced(req, None)
    }

    /// [`submit`](Self::submit) with an optional client-visible request
    /// id recorded against the ticket so trace events carry the id the
    /// caller knows (mirrors `Engine::submit_traced`).
    pub fn submit_traced(&mut self, req: &GenRequest, trace_id: Option<u64>) -> Result<u64> {
        let ids = Self::encode(&req.prompt);
        if ids.len() + 2 > req.max_len {
            bail!(
                "prompt ({} tokens) does not fit max_len {}",
                ids.len(),
                req.max_len
            );
        }
        if req.max_len > self.cfg.geom.slots {
            bail!(
                "max_len {} exceeds slot capacity {}",
                req.max_len,
                self.cfg.geom.slots
            );
        }
        let mut prefix_pages: Vec<u64> = Vec::new();
        let mut prefix_tokens = 0usize;
        if self.cfg.prefix_cache {
            self.metrics.counter("kv.prefix_lookups").inc();
            let mut hit = self.prefix_index.lookup(&ids);
            // cold tier: promote demoted pages extending the hot hit
            // back into the pool (mirrors `Engine::submit_traced`)
            if self.cold.enabled() {
                let promoted = self.promote_cold_hits(&ids, hit.tokens);
                if promoted > 0 {
                    self.metrics.counter("kv.cold_hits").inc();
                    self.metrics
                        .counter("kv.cold_hit_tokens")
                        .add((promoted * self.cfg.geom.page_size) as f64);
                    hit = self.prefix_index.lookup(&ids);
                }
            }
            if hit.tokens > 0 {
                self.metrics.counter("kv.prefix_hits").inc();
                self.metrics
                    .counter("kv.prefix_hit_tokens")
                    .add(hit.tokens as f64);
                for _ in 0..req.width.max(1) {
                    for &id in &hit.pages {
                        self.cache.retain_page(id);
                    }
                }
                prefix_pages = hit.pages;
                prefix_tokens = hit.tokens;
            }
        }
        let prompt_tokens = ids.len();
        let ticket =
            self.sched
                .submit_with_prefix(req, Arc::new(ids), &prefix_pages, prefix_tokens);
        if self.tracer.enabled() {
            let rid = trace_id.unwrap_or(ticket);
            self.trace_ids.insert(ticket, rid);
            let ts = self.now_ns();
            self.tracer.emit(
                ts,
                TraceEvent::Submit {
                    req: rid,
                    prompt_tokens,
                    width: req.width.max(1),
                    prefix_hit_tokens: prefix_tokens,
                },
            );
        }
        Ok(ticket)
    }

    /// Promote consecutive cold-tier pages extending a hot hit back
    /// into the pool and re-index them (mirrors
    /// `Engine::promote_cold_hits`; see that method for the
    /// never-re-encode contract). Returns the promoted page count.
    fn promote_cold_hits(&mut self, ids: &[u32], hot_tokens: usize) -> usize {
        let ps = self.cfg.geom.page_size;
        if ids.is_empty() {
            return 0;
        }
        let max_pages = (ids.len() - 1) / ps;
        let mut k = hot_tokens / ps;
        let mut adopted: BTreeMap<usize, PageId> = BTreeMap::new();
        while k < max_pages {
            let key = &ids[..(k + 1) * ps];
            let Some((page, data)) = self.cold.promote(key) else {
                break;
            };
            let id = self.cache.adopt_cold_page(page, data);
            adopted.insert(k, id);
            k += 1;
        }
        if adopted.is_empty() {
            return 0;
        }
        let n = adopted.len();
        self.prefix_index.insert(&ids[..k * ps], |p| {
            adopted.remove(&p).expect("promoted page index")
        });
        n
    }

    /// Single typed submit entrypoint (mirrors `Engine::submit_spec`):
    /// one [`SubmitSpec`] carries the request, trace id, and optional
    /// SLO tier — what the serving `Backend` trait's sole `submit`
    /// calls.
    pub fn submit_spec(&mut self, spec: &SubmitSpec) -> Result<u64> {
        let ticket = self.submit_traced(&spec.request, spec.trace_id)?;
        if let Some(tier) = spec.slo {
            self.assign_slo(ticket, tier);
        }
        Ok(ticket)
    }

    /// Stamp a submitted ticket with its SLO tier (mirrors
    /// `Engine::assign_slo`): scheduler tier + absolute e2e deadline
    /// on the sim's tick clock, acceptance counted.
    pub fn assign_slo(&mut self, ticket: u64, tier: SloTier) {
        let deadline_ns = self.now_ns() + tier.e2e_deadline_ns();
        self.sched.assign_slo(ticket, tier, deadline_ns);
        self.metrics.counter("serve.slo_accepted").inc();
        if self.tracer.enabled() {
            let req = self.trace_req(ticket);
            let ts = self.now_ns();
            self.tracer.emit(
                ts,
                TraceEvent::SloAssigned {
                    req,
                    tier: tier.name(),
                    ttft_deadline_ns: ts + tier.ttft_deadline_ns(),
                    e2e_deadline_ns: deadline_ns,
                },
            );
        }
    }

    /// Outstanding pool references across all retained/shared pages —
    /// the leak probe steal and retirement tests balance against: a
    /// drained request must return this to its pre-submit value (refs
    /// released exactly once; a double release panics in the pool).
    pub fn pool_refs(&self) -> usize {
        self.cache.pool_refs()
    }

    /// Work-stealing handoff (mirrors `Engine::drain_queued`): remove
    /// up to `max_requests` fresh queued requests, release the prefix
    /// references they held, return their tickets.
    pub fn drain_queued(&mut self, max_requests: usize) -> Vec<u64> {
        let drained = self.sched.drain_queued(max_requests);
        let mut tickets = Vec::with_capacity(drained.len());
        for (ticket, chains) in drained {
            for chain in chains {
                for id in chain.prefix_pages {
                    self.cache.release_page(id);
                }
            }
            // the stealing router re-submits elsewhere; this engine's
            // trace of the request ends here
            self.trace_ids.remove(&ticket);
            tickets.push(ticket);
        }
        tickets
    }

    fn sim_policy(&self, max_len: usize) -> Box<dyn Policy> {
        build_policy(
            PolicyKind::Vanilla,
            1.0,
            max_len,
            4,
            self.cfg.geom.page_size,
        )
    }

    /// Budget plan a chain of `max_len` would run under (CR 1 — the
    /// sim decodes dense). Drives the per-replica `kv.plan_*` gauges
    /// so cluster stats expose plan summaries without AOT artifacts.
    fn plan_for(&self, max_len: usize) -> BudgetPlan {
        let g = self.cfg.geom;
        self.allocator
            .plan(g.layers, g.kv_heads, max_len.max(1) * g.lh(), None)
    }

    /// Per-token "executor" cost: write the token's K/V into every
    /// (layer, head) of the lane, plus the configured spin work.
    /// Returns false on cache overflow.
    fn write_token(&mut self, lane: usize, tok: u32, pos: usize) -> bool {
        let g = self.cfg.geom;
        let payload: Vec<f32> = (0..g.head_dim)
            .map(|d| tok as f32 * 0.125 + pos as f32 * 0.25 + d as f32 * 0.0625)
            .collect();
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                match self.cache.alloc_slot(lane, l, h) {
                    Some(s) => self.cache.write(lane, l, h, s, pos, &payload, &payload),
                    None => return false,
                }
            }
        }
        // deterministic spin standing in for model FLOPs
        let mut acc = self.spin;
        for i in 0..self.cfg.work_per_token {
            acc = (acc + i as f32 * 1.0e-7).sin();
        }
        self.spin = std::hint::black_box(acc);
        true
    }

    /// Advance the sim by one scheduler tick (mirrors `Engine::tick`):
    /// admit, prefill one chunk per prefilling lane, decode one token
    /// per decoding lane, retire finished chains, record metrics.
    pub fn tick(&mut self) -> Result<Vec<CompletedRequest>> {
        let mut completed = Vec::new();
        self.admit();
        if self.sched.active_lanes() == 0 {
            return Ok(completed);
        }
        self.stats.ticks += 1;
        self.tick_read_tokens = 0.0;
        let t0 = Instant::now();
        self.prefill_step(&mut completed);
        self.decode_step(&mut completed);
        self.stats.host_s += t0.elapsed().as_secs_f64();

        if self.tracer.enabled() {
            let ts = self.now_ns();
            for (lane, ev) in self.cache.drain_tick_events() {
                if ev.cow_published > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::CowPublish {
                            lane,
                            pages: ev.cow_published,
                        },
                    );
                }
                if ev.dequant_pages > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::Dequant {
                            lane,
                            pages: ev.dequant_pages,
                        },
                    );
                }
                if ev.evictions + ev.merges > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::EvictBatch {
                            lane,
                            evictions: ev.evictions,
                            merges: ev.merges,
                            lh_touched: ev.lh_touched,
                        },
                    );
                }
            }
        }
        if self.tick_read_tokens > 0.0 {
            self.metrics
                .counter("kv.read_tokens")
                .add(self.tick_read_tokens);
            self.metrics
                .counter("kv.read_bytes")
                .add(self.tick_read_tokens * self.kv_bytes_per_token());
        }

        self.metrics
            .gauge("engine.active_lanes")
            .set(self.sched.active_lanes() as f64);
        self.metrics
            .gauge("engine.queue_depth")
            .set(self.sched.queue_depth() as f64);
        self.metrics
            .gauge("kv.live_fraction")
            .set(self.cache.live_fraction());
        self.metrics
            .gauge("kv.pool_pages")
            .set(self.cache.pool_pages() as f64);
        // tiered prefix-cache accounting (mirrors `Engine::tick`)
        let cache = &self.cache;
        let mut retained_bytes = 0usize;
        self.prefix_index
            .for_each_page(|id| retained_bytes += cache.page_payload_bytes(id));
        self.metrics
            .gauge("kv.prefix_retained_bytes")
            .set(retained_bytes as f64);
        self.metrics
            .gauge("kv.cold_tier_bytes")
            .set(self.cold.resident_bytes() as f64);
        self.metrics
            .gauge("kv.spilled_bytes")
            .set(self.cold.spilled_bytes() as f64);
        self.metrics
            .gauge("kv.cold_promote_us")
            .set(self.cold.promote_us() as f64);
        // per-replica plan summaries, aggregated across active lanes
        // exactly like the real engine's tick (the sim's vanilla
        // policy is unbudgeted — these report the plans the configured
        // allocator shapes for the running chains) and dropping to
        // zero once the lanes drain
        let g = self.cfg.geom;
        let mut plan_lanes = 0usize;
        let mut plan_tokens = 0usize;
        let mut plan_min = usize::MAX;
        let mut plan_max = 0usize;
        for lane in 0..self.sched.n_lanes() {
            let Some(a) = self.sched.lane(lane) else { continue };
            let plan = self.plan_for(a.max_len);
            plan_lanes += 1;
            plan_tokens += plan.total(g.layers, g.kv_heads);
            plan_min = plan_min.min(plan.min_budget());
            plan_max = plan_max.max(plan.max_budget());
        }
        self.metrics.gauge("kv.plan_lanes").set(plan_lanes as f64);
        self.metrics.gauge("kv.plan_tokens").set(plan_tokens as f64);
        self.metrics
            .gauge("kv.plan_min_lh")
            .set(if plan_lanes > 0 { plan_min as f64 } else { 0.0 });
        self.metrics.gauge("kv.plan_max_lh").set(plan_max as f64);
        let bpt = self.kv_bytes_per_token();
        for c in &completed {
            let t = &c.timing;
            self.metrics.histogram("serve.queue_ms").record(t.queue_ms);
            self.metrics.histogram("serve.ttft_ms").record(t.ttft_ms);
            self.metrics.histogram("serve.e2e_ms").record(t.e2e_ms);
            self.metrics
                .histogram("serve.req_tokens_per_s")
                .record(t.tokens_per_s());
            self.metrics.counter("serve.requests").inc();
            self.metrics
                .counter("serve.gen_tokens")
                .add(t.gen_tokens as f64);
            if let Some(tier) = c.slo {
                let ttft_budget_ms = tier.ttft_deadline_ns() as f64 / 1e6;
                let e2e_budget_ms = tier.e2e_deadline_ns() as f64 / 1e6;
                if t.ttft_ms > ttft_budget_ms {
                    self.metrics.counter("serve.slo_ttft_miss").inc();
                }
                if t.e2e_ms > e2e_budget_ms {
                    self.metrics.counter("serve.slo_deadline_miss").inc();
                } else {
                    self.metrics
                        .counter("serve.slo_goodput_tokens")
                        .add(t.gen_tokens as f64);
                }
            }
            let reads = c.result.total_reads();
            self.metrics.histogram("serve.kv_read_tokens").record(reads);
            if self.tracer.enabled() {
                let req = self.trace_req(c.ticket);
                let ts = self.now_ns();
                self.tracer.emit(
                    ts,
                    TraceEvent::Finish {
                        req,
                        gen_tokens: t.gen_tokens,
                        read_tokens: reads,
                        read_bytes: reads * bpt,
                    },
                );
            }
            self.trace_ids.remove(&c.ticket);
        }
        Ok(completed)
    }

    /// Run every submitted request to completion (static-batch
    /// convenience for benches/tests).
    pub fn drain(&mut self) -> Result<Vec<CompletedRequest>> {
        let mut out = Vec::new();
        let mut ticks = 0u64;
        while !self.is_idle() {
            out.extend(self.tick()?);
            ticks += 1;
            assert!(ticks < 1_000_000, "sim failed to drain");
        }
        Ok(out)
    }

    fn admit(&mut self) {
        while let Some(lane) = self.sched.idle_lane() {
            let Some(mut p) = self.sched.next_admission() else { break };
            self.cache.reset_lane(lane);
            let ticket = p.ticket;
            let prefix_pages = std::mem::take(&mut p.prefix_pages);
            let prefix_tokens = p.prefix_tokens;
            let restored_pages = prefix_pages.len();
            let policy = self.sim_policy(p.max_len);
            let mut chain = ChainState::new(p, policy, 0);
            if !prefix_pages.is_empty() {
                self.cache.map_prefix_pages(lane, &prefix_pages);
                chain.phase = Phase::Prefill {
                    offset: prefix_tokens,
                };
                chain.stats.prefix_hit_tokens = prefix_tokens;
                self.stats.prefix_hit_tokens += prefix_tokens as u64;
            }
            self.sched.install(lane, chain);
            if self.tracer.enabled() {
                let req = self.trace_req(ticket);
                let ts = self.now_ns();
                self.tracer.emit(ts, TraceEvent::Admit { req, lane });
                if restored_pages > 0 {
                    self.tracer.emit(
                        ts,
                        TraceEvent::PrefixRestore {
                            req,
                            lane,
                            pages: restored_pages,
                            tokens: prefix_tokens,
                        },
                    );
                }
            }
        }
    }

    fn prefill_step(&mut self, completed: &mut Vec<CompletedRequest>) {
        let lanes = self.sched.n_lanes();
        let mut did_work = false;
        for lane in 0..lanes {
            let (offset, ids, live_before) = {
                let Some(a) = self.sched.lane(lane) else { continue };
                let Phase::Prefill { offset } = a.phase else { continue };
                (offset, a.prefill_ids.clone(), self.cache.live_tokens(lane))
            };
            // shared pages mapped at admission must be resident before
            // this lane's "executor" reads/extends them
            self.cache.materialize_pending();
            let n = (ids.len() - offset).min(self.cfg.chunk);
            let mut overflow = false;
            for j in 0..n {
                let pos = offset + j;
                if !self.write_token(lane, ids[pos], pos) {
                    overflow = true;
                    break;
                }
                let step_reads = live_before + (j + 1) as f64;
                self.sched.lane_mut(lane).unwrap().stats.prefill_reads += step_reads;
                self.tick_read_tokens += step_reads;
            }
            did_work = true;
            if overflow {
                let chain = self.sched.take(lane).unwrap();
                if let Some(done) = self.finish_chain(chain, lane, FinishReason::Overflow) {
                    completed.push(done);
                }
                continue;
            }
            let peak = self.cache.live_tokens(lane);
            let a = self.sched.lane_mut(lane).unwrap();
            if peak > a.stats.peak_tokens {
                a.stats.peak_tokens = peak;
            }
            let new_offset = offset + n;
            if new_offset == a.prefill_ids.len() {
                let resumed = a.resume_token.is_some();
                let tok = match a.resume_token.take() {
                    Some(t) => t,
                    None => a.sampler.sample(&sim_logits(new_offset - 1)),
                };
                a.cur_token = tok;
                a.pos = new_offset;
                a.phase = Phase::Decode;
                let ticket = a.ticket;
                if self.sched.note_first_token(ticket) && self.tracer.enabled() {
                    let req = self.trace_req(ticket);
                    let ts = self.now_ns();
                    self.tracer.emit(ts, TraceEvent::FirstToken { req });
                }
                if !resumed {
                    self.fork_siblings(lane, ticket, tok, new_offset);
                }
            } else {
                a.phase = Phase::Prefill { offset: new_offset };
            }
        }
        if did_work {
            self.stats.prefill_chunks += 1;
        }
    }

    fn fork_siblings(&mut self, src_lane: usize, ticket: u64, tok: u32, pos: usize) {
        loop {
            let Some(dst) = self.sched.idle_lane() else { break };
            let Some(mut p) = self.sched.take_fork_sibling(ticket) else { break };
            for id in std::mem::take(&mut p.prefix_pages) {
                self.cache.release_page(id);
            }
            let shared = self.cache.fork_lane_cow(src_lane, dst);
            self.metrics
                .counter("kv.fork_shared_pages")
                .add(shared as f64);
            let policy = self.sim_policy(p.max_len);
            self.sched
                .install(dst, ChainState::forked(p, policy, 0, tok, pos));
            self.stats.forks += 1;
        }
    }

    fn decode_step(&mut self, completed: &mut Vec<CompletedRequest>) {
        let lanes = self.sched.n_lanes();
        self.cache.materialize_pending();
        let mut did_work = false;
        for lane in 0..lanes {
            let (cur, pos, reads) = {
                let Some(a) = self.sched.lane(lane) else { continue };
                if !matches!(a.phase, Phase::Decode) {
                    continue;
                }
                (a.cur_token, a.pos, self.cache.live_tokens(lane) + 1.0)
            };
            did_work = true;
            let wrote = self.write_token(lane, cur, pos);
            let peak = self.cache.live_tokens(lane);
            self.tick_read_tokens += reads;
            let finish = {
                let a = self.sched.lane_mut(lane).unwrap();
                a.stats.decode_reads += reads;
                if peak > a.stats.peak_tokens {
                    a.stats.peak_tokens = peak;
                }
                let tok = a.sampler.sample(&sim_logits(a.pos));
                a.gen_ids.push(a.cur_token);
                a.pos += 1;
                a.cur_token = tok;
                if !wrote {
                    Some(FinishReason::Overflow)
                } else if tok == SIM_EOS {
                    Some(FinishReason::Stop)
                } else if a.pos + 1 >= a.max_len {
                    a.gen_ids.push(tok);
                    Some(FinishReason::Length)
                } else {
                    None
                }
            };
            if let Some(reason) = finish {
                let chain = self.sched.take(lane).unwrap();
                if let Some(done) = self.finish_chain(chain, lane, reason) {
                    completed.push(done);
                }
            }
        }
        if did_work {
            self.stats.decode_steps += 1;
        }
    }

    /// Retire a chain: final stats, prefix retention, lane recycling
    /// (mirrors `Engine::finish_chain`).
    fn finish_chain(
        &mut self,
        mut a: ChainState,
        lane: usize,
        finish: FinishReason,
    ) -> Option<CompletedRequest> {
        a.stats.final_tokens = self.cache.live_tokens(lane);
        a.stats.gen_tokens = a.gen_ids.len();
        a.stats.wall_s += a.started.elapsed().as_secs_f64();
        // the sim's "text" is the raw generated id stream — stable,
        // comparable across schedules, and never decoded for display
        let text = format!("{:?}", a.gen_ids);
        let mut indexed = false;
        if self.cfg.prefix_cache {
            let n = self.cache.clean_prefix_pages(lane, a.stats.prompt_tokens);
            if n > 0 {
                let ps = self.cfg.geom.page_size;
                let ids = &a.prefill_ids[..n * ps];
                let cache = &mut self.cache;
                self.prefix_index
                    .insert(ids, |p| cache.export_page(lane, p));
                indexed = true;
            }
        }
        let freed = self.cache.recycle_lane(lane);
        self.metrics.counter("kv.slots_recycled").add(freed as f64);
        // trim after the lane released its shares (mirrors
        // `Engine::finish_chain`, see the ordering note there)
        if indexed {
            if self.cold.enabled() {
                let cache = &mut self.cache;
                let cold = &mut self.cold;
                self.prefix_index
                    .trim_with(self.cfg.prefix_cache_pages, |key, id| {
                        if let Some((page, data)) = cache.demote_page(id) {
                            cold.admit(key, page, data);
                        }
                    });
            } else {
                for id in self.prefix_index.trim(self.cfg.prefix_cache_pages) {
                    self.cache.release_page(id);
                }
            }
            self.metrics
                .gauge("kv.prefix_pages_retained")
                .set(self.prefix_index.pages_retained() as f64);
        }
        self.sched.complete(
            a.ticket,
            a.chain_idx,
            ChainResult {
                text,
                finish,
                stats: a.stats,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: &str, width: usize, max_len: usize, seed: u64) -> GenRequest {
        GenRequest {
            prompt: prompt.into(),
            width,
            max_len,
            temperature: 0.7,
            seed,
        }
    }

    #[test]
    fn sim_streams_are_schedule_independent() {
        // one at a time on one lane
        let mut solo_texts = Vec::new();
        for i in 0..4u64 {
            let mut e = SimEngine::new(SimEngineConfig {
                lanes: 1,
                ..Default::default()
            });
            e.submit(&req("Q:1+2=?|T:", 1, 96, 100 + i)).unwrap();
            let done = e.drain().unwrap();
            solo_texts.push(done[0].result.chains[0].text.clone());
        }
        // all four share two lanes
        let mut e = SimEngine::new(SimEngineConfig {
            lanes: 2,
            ..Default::default()
        });
        let tickets: Vec<u64> = (0..4u64)
            .map(|i| e.submit(&req("Q:1+2=?|T:", 1, 96, 100 + i)).unwrap())
            .collect();
        let done = e.drain().unwrap();
        assert_eq!(done.len(), 4);
        for (i, t) in tickets.iter().enumerate() {
            let d = done.iter().find(|d| d.ticket == *t).unwrap();
            assert_eq!(d.result.chains[0].text, solo_texts[i], "request {i}");
        }
    }

    #[test]
    fn repeated_prompts_hit_the_prefix_cache() {
        let mut e = SimEngine::new(SimEngineConfig::default());
        let prompt = "system: a long shared preamble spanning multiple pages|Q:2*3=?";
        let mut texts = Vec::new();
        let mut hits = Vec::new();
        for _ in 0..3 {
            // same seed every time: streams must match across repeats
            e.submit(&req(prompt, 1, 160, 7)).unwrap();
            let done = e.drain().unwrap();
            hits.push(done[0].result.chains[0].stats.prefix_hit_tokens);
            texts.push(done[0].result.chains[0].text.clone());
        }
        assert_eq!(hits[0], 0, "first request can never hit");
        assert!(hits[1] > 0, "second request restores the prefix");
        assert!(hits[2] >= hits[1]);
        // identical seeds -> identical streams, with or without the hit
        assert_eq!(texts[0], texts[1]);
        assert_eq!(texts[1], texts[2]);
    }

    #[test]
    fn trimmed_prefixes_come_back_through_the_cold_tier() {
        // hot budget far below the prompt's page count: every
        // retention immediately demotes the whole prefix to the cold
        // tier, so repeats can only hit through promotion
        let mut e = SimEngine::new(SimEngineConfig {
            prefix_cache_pages: 2,
            cold_tier_bytes: 1 << 20,
            ..Default::default()
        });
        let prompt = "system: a long shared preamble spanning multiple pages|Q:2*3=?";
        let mut texts = Vec::new();
        let mut hits = Vec::new();
        for _ in 0..3 {
            e.submit(&req(prompt, 1, 160, 7)).unwrap();
            let done = e.drain().unwrap();
            hits.push(done[0].result.chains[0].stats.prefix_hit_tokens);
            texts.push(done[0].result.chains[0].text.clone());
        }
        assert_eq!(hits[0], 0, "first request can never hit");
        assert!(hits[1] > 0, "cold promotion restored the prefix");
        assert!(e.metrics.counter("kv.cold_hits").get() >= 1.0);
        assert_eq!(
            e.metrics.counter("kv.cold_hit_tokens").get(),
            (hits[1] + hits[2]) as f64,
            "every hit token flowed through promotion (hot budget < prefix)"
        );
        // promoted restores decode the q4 lattice: the stream itself
        // must stay identical (sim logits ignore cache payloads)
        assert_eq!(texts[0], texts[1]);
        assert_eq!(texts[1], texts[2]);
        // nothing leaks: index refs + cold entries balance out after
        // the final retention demoted the prefix again
        assert!(e.is_idle());
        assert!(e.metrics.gauge("kv.cold_tier_bytes").get() > 0.0);
    }

    #[test]
    fn width_requests_fork_and_complete() {
        let mut e = SimEngine::new(SimEngineConfig::default());
        e.submit(&req("Q:9-5=?|T:", 3, 96, 11)).unwrap();
        let done = e.drain().unwrap();
        assert_eq!(done.len(), 1);
        let chains = &done[0].result.chains;
        assert_eq!(chains.len(), 3);
        assert!(chains.iter().any(|c| c.stats.forked_prefill));
        assert!(e.stats().forks >= 1);
        // cache fully drained after retirement
        assert_eq!(e.active_lanes(), 0);
    }

    #[test]
    fn drain_queued_releases_prefix_refs_and_requeues_elsewhere() {
        let mut e = SimEngine::new(SimEngineConfig {
            lanes: 1,
            ..Default::default()
        });
        let prompt = "system: a long shared preamble spanning multiple pages|Q:5";
        // seed the prefix index
        e.submit(&req(prompt, 1, 160, 1)).unwrap();
        e.drain().unwrap();
        // saturate the single lane, then queue two more with hits
        e.submit(&req(prompt, 1, 160, 2)).unwrap();
        e.tick().unwrap(); // installs request 2
        e.submit(&req(prompt, 1, 160, 3)).unwrap();
        e.submit(&req(prompt, 1, 160, 4)).unwrap();
        assert_eq!(e.stealable_requests(), 2);
        let stolen = e.drain_queued(8);
        assert_eq!(stolen.len(), 2, "both queued requests handed off");
        assert_eq!(e.stealable_requests(), 0);
        // the running request is untouched and still completes
        let done = e.drain().unwrap();
        assert_eq!(done.len(), 1);
        // no pool leak: every reference the stolen chains held on
        // retained pages was released (index refs remain)
        assert_eq!(e.queue_depth(), 0);
    }

    #[test]
    fn plan_gauges_reflect_allocator() {
        let mut e = SimEngine::new(SimEngineConfig {
            allocator: AllocatorKind::Pyramid,
            ..Default::default()
        });
        e.submit(&req("Q:1+1=?|T:", 1, 96, 3)).unwrap();
        e.tick().unwrap();
        // one active lane; default geom: 2 layers × 2 heads, CR 1 →
        // 96 tokens per cell
        assert_eq!(e.metrics.gauge("kv.plan_lanes").get(), 1.0);
        assert_eq!(e.metrics.gauge("kv.plan_tokens").get(), 96.0 * 4.0);
        let min = e.metrics.gauge("kv.plan_min_lh").get();
        let max = e.metrics.gauge("kv.plan_max_lh").get();
        assert!(max > min, "pyramid plans are non-uniform: {min} vs {max}");
        // gauges drop to zero once the lanes drain (no stale reads)
        e.drain().unwrap();
        assert_eq!(e.metrics.gauge("kv.plan_lanes").get(), 0.0);
        assert_eq!(e.metrics.gauge("kv.plan_tokens").get(), 0.0);
    }

    #[test]
    fn tracing_records_lifecycle_and_read_counters() {
        let mut e = SimEngine::new(SimEngineConfig {
            trace_events: 256,
            ..Default::default()
        });
        e.submit_traced(&req("Q:1+2=?|T:", 1, 96, 5), Some(42)).unwrap();
        e.drain().unwrap();
        let names: Vec<&str> = e
            .trace_events_for(42)
            .iter()
            .map(|s| s.event.name())
            .collect();
        assert_eq!(names, ["submit", "admit", "first_token", "finish"]);
        // memory-read accounting flows through the same tick path
        let toks = e.metrics.counter("kv.read_tokens").get();
        let bytes = e.metrics.counter("kv.read_bytes").get();
        assert!(toks > 0.0);
        assert_eq!(bytes, toks * e.kv_bytes_per_token());
        assert_eq!(e.tracer().dropped(), 0);
    }

    #[test]
    fn tracing_disabled_records_nothing() {
        let mut e = SimEngine::new(SimEngineConfig::default());
        e.submit(&req("Q:1+2=?|T:", 1, 96, 5)).unwrap();
        e.drain().unwrap();
        assert!(!e.tracer().enabled());
        assert_eq!(e.tracer().recorded(), 0);
        assert!(e.tracer().events().is_empty());
    }

    #[test]
    fn overflowing_prompt_is_rejected_at_submit() {
        let mut e = SimEngine::new(SimEngineConfig::default());
        let long = "x".repeat(400);
        assert!(e.submit(&req(&long, 1, 160, 0)).is_err());
        assert!(e.submit(&req("ok", 1, 400, 0)).is_err(), "max_len > slots");
    }
}
