//! Verifier-free parallel-scaling aggregation (paper §2.1/§4):
//! majority voting over exact-match answers, and pass@all for code.

use std::collections::BTreeMap;

use crate::tasks::extract_answer;

/// Aggregated outcome over W chains.
#[derive(Clone, Debug, PartialEq)]
pub struct VoteOutcome {
    /// The winning answer (majority) if any chain produced one.
    pub answer: Option<String>,
    /// Votes per distinct answer.
    pub votes: BTreeMap<String, usize>,
    /// Number of chains that produced any parseable answer.
    pub answered: usize,
}

/// Majority vote over the extracted answers of W generations.
/// Ties break toward the answer that first reached the winning count
/// (stable across runs).
pub fn majority_vote(texts: &[&str]) -> VoteOutcome {
    let mut votes: BTreeMap<String, usize> = BTreeMap::new();
    let mut order: Vec<String> = Vec::new();
    let mut answered = 0;
    for t in texts {
        if let Some(a) = extract_answer(t) {
            answered += 1;
            let e = votes.entry(a.clone()).or_insert(0);
            *e += 1;
            if !order.contains(&a) {
                order.push(a);
            }
        }
    }
    let mut best: Option<(String, usize)> = None;
    for a in &order {
        let c = votes[a];
        if best.as_ref().map(|(_, bc)| c > *bc).unwrap_or(true) {
            best = Some((a.clone(), c));
        }
    }
    VoteOutcome {
        answer: best.map(|(a, _)| a),
        votes,
        answered,
    }
}

/// pass@all: correct if ANY chain's answer matches (LiveCodeBench
/// scoring in the paper).
pub fn pass_at_all(texts: &[&str], gold: &str) -> bool {
    texts
        .iter()
        .any(|t| extract_answer(t).as_deref() == Some(gold))
}

/// Task-appropriate aggregation: pass@all for code suites, majority
/// vote otherwise. Returns whether the request counts as correct.
pub fn aggregate(task: &str, texts: &[&str], gold: &str) -> bool {
    if task == "lcb" || task == "hellaswag" || task == "code" {
        pass_at_all(texts, gold)
    } else {
        majority_vote(texts).answer.as_deref() == Some(gold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn majority_picks_most_common() {
        let texts = ["x A:7\n", "y A:7\n", "z A:3\n"];
        let v = majority_vote(&texts);
        assert_eq!(v.answer.as_deref(), Some("7"));
        assert_eq!(v.votes["7"], 2);
        assert_eq!(v.answered, 3);
    }

    #[test]
    fn tie_breaks_to_first_seen() {
        let texts = ["A:1\n", "A:2\n"];
        let v = majority_vote(&texts);
        assert_eq!(v.answer.as_deref(), Some("1"));
    }

    #[test]
    fn unanswered_chains_ignored() {
        let texts = ["gibberish", "A:5\n"];
        let v = majority_vote(&texts);
        assert_eq!(v.answer.as_deref(), Some("5"));
        assert_eq!(v.answered, 1);
    }

    #[test]
    fn pass_at_all_needs_one_hit() {
        assert!(pass_at_all(&["A:1\n", "A:9\n"], "9"));
        assert!(!pass_at_all(&["A:1\n", "A:2\n"], "9"));
    }

    #[test]
    fn aggregate_dispatches_by_task() {
        // lcb: any hit counts even when the majority is wrong
        assert!(aggregate("lcb", &["A:0\n", "A:0\n", "A:9\n"], "9"));
        // math: majority must match
        assert!(!aggregate("math", &["A:0\n", "A:0\n", "A:9\n"], "9"));
        assert!(aggregate("math", &["A:9\n", "A:9\n", "A:0\n"], "9"));
    }
}
