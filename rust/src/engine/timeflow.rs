//! Discrete-event cluster timing simulator ("timeflow").
//!
//! The live cluster ([`crate::server::cluster`]) has threads and
//! channels but no notion of *time*: routing, stealing, and allocator
//! policies can only be compared on counters. Timeflow gives the same
//! decision cores a virtual clock — a discrete-event simulation over
//! integer nanoseconds in which every per-request stage
//!
//! ```text
//! arrival → [queue] → dequant-on-upload → prefill → first token → decode
//!                 ↘ (steal transfer between replicas) ↗
//! ```
//!
//! is an event with a cycle-stamped completion. Costs are *priced*,
//! not measured: [`CostModel::price`] converts the App. G latency
//! model ([`crate::analysis::LatencyModel`], Eqs. 2–6, H100 peaks)
//! plus the quantized-payload byte geometry
//! ([`KvDtype::row_payload_bytes`] — the same quantity the engine's
//! `kv.bytes_per_token` / `kv.dequant_us` gauges measure) into fixed
//! per-token nanosecond constants. The result is a **deterministic
//! perf model**: the same seed yields bit-identical histograms on any
//! machine, so p50/p99/p999 TTFT and aggregate tokens/s become
//! CI-gateable quantities (`bench_sim` → `BENCH_sim.json` →
//! `tools/bench_compare.py`).
//!
//! ## Wiring into the server stack
//!
//! Timeflow does not reimplement routing or steal planning — it drives
//! the *real* [`Router`] (shadow prefix indexes, least-loaded scoring,
//! steal plans) with synthetic [`ReplicaLoad`] snapshots, and shares
//! the cluster's dead-replica degradation rules via
//! [`crate::server::router::mask_dead`] /
//! [`crate::server::router::first_alive`]. Semantics mirrored from the
//! live cluster:
//!
//! * steals take **queued work only**, youngest-first — exactly the
//!   `Scheduler::drain_queued` contract (never an installed or resumed
//!   chain);
//! * a routing decision landing on a dead replica degrades to the
//!   first live replica; dead replicas are masked out of steal
//!   planning so they never donate or look idle;
//! * requests queued on a replica at the moment it dies are re-routed
//!   (none lost, none duplicated); requests already *running* there
//!   are answered-with-error, i.e. counted as `failed`.
//!
//! ## Modeling simplifications
//!
//! One lane serves one request end-to-end (admission-level concurrency
//! is `lanes`; batching economics are folded into the decode price at
//! a reference batch). Prompt token counts are a pure function of the
//! prompt id, so a prefix hit always refers to an identical prompt.
//! Prefix retention is an LRU over prompt ids per replica, populated
//! at request completion — an intentional simplification of the radix
//! index (docs/ARCHITECTURE.md) that preserves the property the router
//! cares about: equal prompts converge, and a hit skips prefill for
//! all but the [`PREFILL_TAIL_TOKENS`] tail (the real index caps hits
//! one page short of the prompt). Re-using a cached prefix is not
//! free: the pages must be re-uploaded — and dequantized, under q8/q4
//! payloads — which is the dequant-on-upload stage.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use super::slo::{AdmissionController, AdmissionDecision, SloPolicy, SloRequest};
use crate::analysis::{Accelerator, LatencyModel, H100};
use crate::compress::{build_allocator, AllocatorKind};
use crate::config::RoutingPolicy;
use crate::kvcache::KvDtype;
use crate::metrics::Registry;
use crate::server::router::{first_alive, mask_dead};
use crate::server::{ReplicaLoad, Router};
use crate::trace::{chrome_trace_json, Stamped, TraceEvent};
use crate::util::rng::SplitMix64;

/// A prefix hit never covers the full prompt: the engine's radix index
/// caps hits one page short so prefill always has work to extend from.
/// Timeflow models that as a fixed uncached tail.
pub const PREFILL_TAIL_TOKENS: usize = 16;

/// Head dim used to convert `d_kv` into per-token KV rows when pricing
/// dequant-on-upload (matches the default engine geometry).
const HEAD_DIM: usize = 64;

/// Reference decode batch for pricing: the steady-state serving regime
/// (paper §5.1 prices KV-read share at batches 64–256).
const REF_BATCH: f64 = 64.0;

/// Reference context length for pricing per-token costs.
const REF_SEQ: f64 = 4096.0;

/// Reference compression ratio handed to the budget allocator: the
/// paper's accuracy-per-cost sweet spot (CR ≈ 4).
const REF_CR: f64 = 4.0;

/// Host→device upload bandwidth (PCIe-class) for cached-prefix pages.
const UPLOAD_BYTES_PER_S: f64 = 64e9;

/// Host dequantization throughput for q8/q4 payloads — the regime the
/// engine's `kv.dequant_us` gauge measures.
const DEQUANT_BYTES_PER_S: f64 = 8e9;

/// Fixed interconnect cost to migrate one queued request descriptor
/// between replicas in a steal.
const TRANSFER_NS: u64 = 50_000;

/// Fixed per-token nanosecond prices for every simulated stage.
///
/// All downstream arithmetic is integer (u64 ns), so a priced model is
/// exactly reproducible; the f64 → ns conversion happens once, here.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Prefill cost per uncached prompt token (compute-bound side).
    pub prefill_ns: u64,
    /// Decode cost per generated token (memory-bound at the reference
    /// batch, divided back to per-token).
    pub decode_ns: u64,
    /// Dequant-on-upload cost per cached prompt token (PCIe upload +
    /// host dequant for quantized payloads).
    pub dequant_ns: u64,
    /// Cold-tier promote cost per cold-hit prompt token: the demoted
    /// block's q4 payload re-uploaded + host-dequantized. Priced at
    /// the cold tier's q4 storage dtype regardless of the hot payload
    /// dtype, so it is the same constant across sweep cells — a cold
    /// hit always pays the compressed-block decode, never a prefill.
    pub cold_hit_ns: u64,
    /// Interconnect cost per stolen-request migration.
    pub transfer_ns: u64,
    /// KV bytes per cached token at this payload dtype — the same
    /// quantity the engine reports as `kv.bytes_per_token`.
    pub kv_bytes_per_token: u64,
}

fn to_ns(seconds: f64) -> u64 {
    (seconds * 1e9).round() as u64
}

impl CostModel {
    /// Price the per-stage constants from the App. G latency model.
    ///
    /// * prefill: Eq. 2 FLOPs at batch 1 over the accelerator's peak —
    ///   prefill is compute-bound;
    /// * decode: Eq. 6 step latency at [`REF_BATCH`], with the KV-read
    ///   term priced at the allocator's planned resident tokens
    ///   (global budget [`REF_SEQ`]`/`[`REF_CR`]; budget-conserving
    ///   plans therefore land at identical decode cost — the plan's
    ///   *total*, not shape, sets the memory-bound share, exactly as
    ///   [`LatencyModel::kv_latency_fraction_planned`] documents);
    /// * dequant: per-token payload bytes over upload bandwidth, plus
    ///   host dequant throughput when the dtype is quantized.
    pub fn price(
        model: &LatencyModel,
        acc: &Accelerator,
        dtype: KvDtype,
        allocator: AllocatorKind,
    ) -> Self {
        let m = model.with_kv_dtype(dtype, HEAD_DIM);
        let prefill_s = m.flops(1.0, REF_SEQ) / acc.flops_per_s;

        let layers = m.n_layers as usize;
        let kv_heads = ((m.d_kv as usize) / HEAD_DIM).max(1);
        let cells = (layers * kv_heads) as f64;
        let global = ((REF_SEQ / REF_CR) * cells) as usize;
        let plan = build_allocator(allocator).plan(layers, kv_heads, global, None);
        let eff_seq = (plan.total(layers, kv_heads) as f64 / cells).min(REF_SEQ);
        let t_compute = m.flops(REF_BATCH, REF_SEQ) / acc.flops_per_s;
        let t_memory =
            (m.reads(REF_BATCH, 0.0) + m.kv_reads(REF_BATCH, eff_seq)) / acc.bytes_per_s;
        let decode_s = t_compute.max(t_memory) / REF_BATCH;

        let rows_per_token = m.n_layers * (m.d_kv / HEAD_DIM as f64) * 2.0;
        let bytes_per_token = rows_per_token * dtype.row_payload_bytes(HEAD_DIM) as f64;
        let mut dequant_s = bytes_per_token / UPLOAD_BYTES_PER_S;
        if dtype.is_quantized() {
            dequant_s += bytes_per_token / DEQUANT_BYTES_PER_S;
        }

        // a cold hit re-uploads the *cold-tier* payload (q4 by
        // default), which is always quantized — upload + host dequant
        let cold_bytes_per_token =
            rows_per_token * KvDtype::Q4.row_payload_bytes(HEAD_DIM) as f64;
        let cold_hit_s = cold_bytes_per_token / UPLOAD_BYTES_PER_S
            + cold_bytes_per_token / DEQUANT_BYTES_PER_S;

        CostModel {
            prefill_ns: to_ns(prefill_s).max(1),
            decode_ns: to_ns(decode_s).max(1),
            dequant_ns: to_ns(dequant_s).max(1),
            cold_hit_ns: to_ns(cold_hit_s).max(1),
            transfer_ns: TRANSFER_NS,
            kv_bytes_per_token: bytes_per_token as u64,
        }
    }

    /// Default pricing: Llama 3.1 8B on an H100.
    pub fn default_for(dtype: KvDtype, allocator: AllocatorKind) -> Self {
        Self::price(&LatencyModel::llama31_8b(), &H100, dtype, allocator)
    }
}

/// Request arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arrival {
    /// Fixed inter-arrival gap (`mean_gap_ns` exactly).
    Uniform,
    /// Exponential inter-arrival gaps (Poisson process).
    Poisson,
    /// Bursts of `burst` simultaneous arrivals, exponential gaps
    /// between bursts.
    Bursty,
}

impl std::str::FromStr for Arrival {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "uniform" => Ok(Arrival::Uniform),
            "poisson" => Ok(Arrival::Poisson),
            "bursty" => Ok(Arrival::Bursty),
            other => Err(anyhow::anyhow!(
                "unknown arrival process '{other}' (uniform|poisson|bursty)"
            )),
        }
    }
}

impl Arrival {
    pub fn name(&self) -> &'static str {
        match self {
            Arrival::Uniform => "uniform",
            Arrival::Poisson => "poisson",
            Arrival::Bursty => "bursty",
        }
    }
}

/// Synthetic workload description: zipf-reused prompts with a chosen
/// arrival process. Fully determined by `seed`.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    pub requests: usize,
    pub seed: u64,
    pub arrival: Arrival,
    /// Mean inter-arrival gap (per request, all replicas combined).
    pub mean_gap_ns: u64,
    /// Burst width for [`Arrival::Bursty`].
    pub burst: usize,
    /// Number of distinct prompts; ids drawn zipf(`zipf_s`).
    pub n_prompts: usize,
    pub zipf_s: f64,
    /// Inclusive prompt-token range; a prompt id always maps to the
    /// same length (so prefix hits are self-consistent).
    pub prompt_tokens: (usize, usize),
    /// Inclusive generated-token range (drawn per request).
    pub gen_tokens: (usize, usize),
}

impl WorkloadSpec {
    /// A small default: 1024 requests, 64 prompts, Poisson arrivals.
    pub fn new(requests: usize, seed: u64) -> Self {
        WorkloadSpec {
            requests,
            seed,
            arrival: Arrival::Poisson,
            mean_gap_ns: 1_250_000,
            burst: 32,
            n_prompts: 64,
            zipf_s: 1.0,
            prompt_tokens: (32, 96),
            gen_tokens: (16, 64),
        }
    }
}

/// One synthetic request, cycle-stamped at generation time.
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub arrival_ns: u64,
    pub prompt_id: usize,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
}

/// Zipf weights for prompt reuse. `s == 1.0` is special-cased to plain
/// division so the weights are bit-reproducible in any IEEE language
/// (no `powf`) — the seeder `tools/seed_bench_sim.py` relies on this.
fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    (1..=n)
        .map(|k| {
            if s == 1.0 {
                1.0 / k as f64
            } else {
                (k as f64).powf(-s)
            }
        })
        .collect()
}

/// Generate the workload for `spec`. Draw order per request is fixed
/// (gap, prompt id, gen tokens) so totals are mirror-computable.
pub fn generate_workload(spec: &WorkloadSpec) -> Vec<SimRequest> {
    assert!(spec.n_prompts > 0 && spec.requests > 0);
    assert!(spec.prompt_tokens.0 > PREFILL_TAIL_TOKENS);
    assert!(spec.prompt_tokens.1 >= spec.prompt_tokens.0);
    assert!(spec.gen_tokens.1 >= spec.gen_tokens.0 && spec.gen_tokens.0 > 0);
    let mut rng = SplitMix64::new(spec.seed);
    let weights = zipf_weights(spec.n_prompts, spec.zipf_s);
    let p_span = spec.prompt_tokens.1 - spec.prompt_tokens.0 + 1;
    let g_span = spec.gen_tokens.1 - spec.gen_tokens.0 + 1;
    let exp_gap = |rng: &mut SplitMix64, mean: u64| -> u64 {
        let u = rng.f64();
        (-(1.0 - u).ln() * mean as f64).round() as u64
    };
    let mut t = 0u64;
    let mut out = Vec::with_capacity(spec.requests);
    for i in 0..spec.requests {
        t += match spec.arrival {
            Arrival::Uniform => spec.mean_gap_ns,
            Arrival::Poisson => exp_gap(&mut rng, spec.mean_gap_ns),
            Arrival::Bursty => {
                if i % spec.burst.max(1) == 0 {
                    exp_gap(&mut rng, spec.mean_gap_ns * spec.burst.max(1) as u64)
                } else {
                    0
                }
            }
        };
        let prompt_id = rng.weighted(&weights);
        let prompt_tokens = spec.prompt_tokens.0 + (prompt_id * 37) % p_span;
        let gen_tokens = spec.gen_tokens.0 + rng.below(g_span);
        out.push(SimRequest {
            arrival_ns: t,
            prompt_id,
            prompt_tokens,
            gen_tokens,
        });
    }
    out
}

/// The byte prompt fed to the router's shadow prefix index for a
/// prompt id. Token counts are synthetic ([`SimRequest`] carries
/// them); this string only has to be long enough to span shadow pages
/// and distinct per id.
pub fn synth_prompt(prompt_id: usize) -> String {
    format!("sim://workload/prompt/{prompt_id:08}|synthetic preamble padding out several shadow pages for affinity scoring")
}

/// A scheduled replica failure: at `at_ns`, `replica` dies — queued
/// requests re-route, running requests fail.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaFailure {
    pub replica: usize,
    pub at_ns: u64,
}

/// Full simulator configuration.
#[derive(Clone, Debug)]
pub struct TimeflowConfig {
    pub replicas: usize,
    /// Concurrent requests per replica.
    pub lanes: usize,
    pub routing: RoutingPolicy,
    pub steal: bool,
    /// Steal-scan period (the cluster scans on status updates; the
    /// simulator scans on a fixed virtual-time cadence).
    pub steal_interval_ns: u64,
    pub kv_dtype: KvDtype,
    pub allocator: AllocatorKind,
    /// Model prefix retention + dequant-on-upload.
    pub prefix_cache: bool,
    /// Per-replica LRU capacity, in distinct prompt ids.
    pub retain_prompts: usize,
    /// Per-replica *cold-tier* LRU capacity, in distinct prompt ids:
    /// prompts evicted from the hot LRU demote here instead of being
    /// forgotten, and a cold hit pays [`CostModel::cold_hit_ns`] per
    /// token instead of a re-prefill. 0 (the default) disables the
    /// tier and keeps the hot-only baselines bit-identical.
    pub cold_retain_prompts: usize,
    pub cost: CostModel,
    pub failure: Option<ReplicaFailure>,
    /// Record per-stage spans + the completion sequence (memory-heavy;
    /// for tests and diagnostics, not million-request sweeps).
    pub record_trace: bool,
}

impl TimeflowConfig {
    pub fn new(replicas: usize, lanes: usize, routing: RoutingPolicy) -> Self {
        let kv_dtype = KvDtype::F32;
        let allocator = AllocatorKind::Uniform;
        TimeflowConfig {
            replicas,
            lanes,
            routing,
            steal: true,
            steal_interval_ns: 1_000_000,
            kv_dtype,
            allocator,
            prefix_cache: true,
            retain_prompts: 256,
            cold_retain_prompts: 0,
            cost: CostModel::default_for(kv_dtype, allocator),
            failure: None,
            record_trace: false,
        }
    }

    /// Set the payload dtype + allocator and re-price the cost model.
    pub fn with_kv(mut self, dtype: KvDtype, allocator: AllocatorKind) -> Self {
        self.kv_dtype = dtype;
        self.allocator = allocator;
        self.cost = CostModel::default_for(dtype, allocator);
        self
    }

    /// `"<routing>/<steal|nosteal>/<dtype>/<allocator>"` — the label
    /// reports and benches key sweep cells by.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/{}",
            self.routing.name(),
            if self.steal { "steal" } else { "nosteal" },
            self.kv_dtype.name(),
            self.allocator.name()
        )
    }
}

/// Per-request pipeline stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Re-upload (+ dequantize) cached prefix pages.
    Dequant,
    /// Promote a cold-tier prefix: upload + dequantize the demoted q4
    /// block (strictly cheaper than the prefill it replaces, costlier
    /// than a hot dequant of the same tokens under f32 payloads).
    ColdHit,
    /// Chunked prefill over uncached prompt tokens.
    Prefill,
    /// First decode step — its completion stamps TTFT.
    FirstToken,
    /// Remaining decode steps.
    Decode,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Dequant => "dequant",
            Stage::ColdHit => "cold_hit",
            Stage::Prefill => "prefill",
            Stage::FirstToken => "first_token",
            Stage::Decode => "decode",
        }
    }
}

/// One executed stage span (recorded when `record_trace` is set).
#[derive(Clone, Copy, Debug)]
pub struct StageSpan {
    pub req: usize,
    pub replica: usize,
    pub stage: Stage,
    pub start_ns: u64,
    pub end_ns: u64,
}

/// Simulation outcome: headline latency/throughput numbers plus the
/// full metrics registry (per-stage histograms, counters, gauges).
#[derive(Debug)]
pub struct SimReport {
    pub label: String,
    pub requests: usize,
    pub completed: usize,
    pub failed: usize,
    pub stolen: u64,
    pub gen_tokens: u64,
    /// Virtual time of the last completion.
    pub span_ns: u64,
    pub ttft_p50_ns: f64,
    pub ttft_p99_ns: f64,
    pub ttft_p999_ns: f64,
    /// Generated tokens per *virtual* second across the cluster.
    pub tokens_per_s: f64,
    /// Busy-lane fraction of `replicas × lanes × span`.
    pub utilization: f64,
    pub registry: Registry,
    /// `(completion_ns, req)` in completion order (trace only).
    pub completions: Vec<(u64, usize)>,
    /// Per-stage spans (trace only).
    pub trace: Vec<StageSpan>,
    /// Goodput under SLO: tokens of requests that met their e2e
    /// deadline, per virtual second (0 unless [`simulate_slo`] ran).
    pub slo_goodput_tokens_per_s: f64,
    /// SLO lifecycle events (`slo_assigned` / `rejected` /
    /// `deadline_miss`), sim-stamped (trace only, [`simulate_slo`]).
    pub slo_events: Vec<Stamped>,
}

impl SimReport {
    /// The recorded stage spans as stamped [`TraceEvent::Stage`]
    /// events, grouped per replica (the Chrome `pid`). Stamps are
    /// **sim time** — the stream, and its Chrome rendering, is a pure
    /// function of the seed, which is what lets CI assert two
    /// same-seed dumps byte-identical. Empty unless
    /// [`TimeflowConfig::record_trace`] was set.
    pub fn trace_events(&self) -> Vec<(usize, Vec<Stamped>)> {
        let replicas = self.trace.iter().map(|s| s.replica + 1).max().unwrap_or(0);
        let mut groups: Vec<(usize, Vec<Stamped>)> =
            (0..replicas).map(|pid| (pid, Vec::new())).collect();
        for (seq, s) in self.trace.iter().enumerate() {
            groups[s.replica].1.push(Stamped {
                ts_ns: s.end_ns,
                seq: seq as u64,
                event: TraceEvent::Stage {
                    req: s.req as u64,
                    replica: s.replica,
                    stage: s.stage.name(),
                    start_ns: s.start_ns,
                },
            });
        }
        // SLO lifecycle events get their own pid row after the
        // replicas (admission decisions are cluster-level, not
        // per-replica); still a pure function of the seed.
        if !self.slo_events.is_empty() {
            groups.push((replicas, self.slo_events.clone()));
        }
        groups
    }

    /// Chrome trace-event JSON (Perfetto-loadable) of the recorded
    /// stage spans — the payload `sim --trace-out` writes.
    pub fn chrome_trace_json(&self) -> String {
        chrome_trace_json(&self.trace_events())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EvKind {
    Arrive { req: usize },
    StageDone { req: usize, stage: Stage },
    TransferDone { req: usize, to: usize },
    StealScan,
    Fail { replica: usize },
}

/// Events order by (time, insertion seq): ties resolve in insertion
/// order, making the whole simulation a pure function of the seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Ev {
    ns: u64,
    seq: u64,
    kind: EvKind,
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.ns, self.seq).cmp(&(other.ns, other.seq))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ReqPhase {
    /// Not yet arrived.
    Pending,
    /// In a replica's admission queue (stealable).
    Queued,
    /// Migrating between replicas.
    InTransfer,
    /// Holding a lane.
    Running,
    Done,
    Failed,
    /// Turned away by admission control; never routed.
    Rejected,
}

#[derive(Clone, Copy, Debug)]
struct ReqState {
    phase: ReqPhase,
    replica: usize,
    hit_tokens: usize,
    /// When the current stage started (Running) or the request was
    /// last enqueued (Queued).
    mark_ns: u64,
}

/// Deterministic LRU over prompt ids (ticks are unique, so the evicted
/// entry is independent of hash iteration order).
struct LruSet {
    map: HashMap<usize, u64>,
    tick: u64,
    cap: usize,
}

impl LruSet {
    fn new(cap: usize) -> Self {
        LruSet {
            map: HashMap::new(),
            tick: 0,
            cap,
        }
    }

    /// True when `k` is resident; refreshes recency on hit.
    fn touch(&mut self, k: usize) -> bool {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&k) {
            Some(t) => {
                *t = tick;
                true
            }
            None => false,
        }
    }

    /// Insert (or refresh) `k`; returns the key evicted to stay under
    /// capacity, if any — the timeflow demotion hook.
    fn insert(&mut self, k: usize) -> Option<usize> {
        self.tick += 1;
        self.map.insert(k, self.tick);
        if self.map.len() > self.cap {
            let evict = self
                .map
                .iter()
                .min_by_key(|&(_, &t)| t)
                .map(|(&k, _)| k)
                .expect("non-empty over cap");
            self.map.remove(&evict);
            return Some(evict);
        }
        None
    }

    /// Drop `k` if resident (the promote-on-hit side: a cold entry
    /// leaves the cold set when it is promoted back to hot).
    fn remove(&mut self, k: usize) -> bool {
        self.map.remove(&k).is_some()
    }
}

/// Per-replica state. Lanes are interchangeable (one request end to
/// end), so a free-lane *count* suffices — no lane ids to track.
struct Rep {
    queue: VecDeque<usize>,
    free_lanes: usize,
    running: usize,
    inflight: usize,
    dead: bool,
    cached: LruSet,
    /// Cold tier: prompts demoted out of `cached`, promoted back on a
    /// cold hit. Probed/populated only when
    /// [`TimeflowConfig::cold_retain_prompts`] is non-zero.
    cold: LruSet,
    busy_ns: u64,
}

impl Rep {
    fn new(lanes: usize, retain_prompts: usize, cold_retain_prompts: usize) -> Self {
        Rep {
            queue: VecDeque::new(),
            free_lanes: lanes,
            running: 0,
            inflight: 0,
            dead: false,
            cached: LruSet::new(retain_prompts.max(1)),
            cold: LruSet::new(cold_retain_prompts.max(1)),
            busy_ns: 0,
        }
    }
}

/// Optional SLO overlay on the simulator: deadline side-tables
/// (parallel to `Sim::reqs`), the EDF dispatch switch, the byte-budget
/// admission controller, and the lifecycle-event sink.
struct SloCtx {
    reqs: Vec<SloRequest>,
    edf: bool,
    admission: Option<AdmissionController>,
    goodput_tokens: u64,
    events: Vec<Stamped>,
}

impl SloCtx {
    fn push_event(&mut self, ns: u64, event: TraceEvent) {
        let seq = self.events.len() as u64;
        self.events.push(Stamped {
            ts_ns: ns,
            seq,
            event,
        });
    }
}

struct Sim<'a> {
    cfg: &'a TimeflowConfig,
    reqs: &'a [SimRequest],
    slo: Option<SloCtx>,
    prompts: Vec<String>,
    router: Router,
    reps: Vec<Rep>,
    st: Vec<ReqState>,
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    settled: usize,
    queued_now: usize,
    reg: Registry,
    completions: Vec<(u64, usize)>,
    trace: Vec<StageSpan>,
    last_completion_ns: u64,
    stolen: u64,
    gen_total: u64,
}

impl<'a> Sim<'a> {
    fn push(&mut self, ns: u64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev {
            ns,
            seq: self.seq,
            kind,
        }));
    }

    fn loads(&self) -> Vec<ReplicaLoad> {
        self.reps
            .iter()
            .map(|r| ReplicaLoad {
                queue_depth: r.queue.len(),
                active_lanes: r.running,
                inflight: r.inflight,
                stealable: r.queue.len(),
            })
            .collect()
    }

    fn dead_mask(&self) -> Vec<bool> {
        self.reps.iter().map(|r| r.dead).collect()
    }

    /// Route a request through the real router, degrading a dead
    /// target to the first live replica exactly as the cluster does.
    fn pick_target(&mut self, req: usize) -> usize {
        let loads = self.loads();
        let prompt = &self.prompts[self.reqs[req].prompt_id];
        let d = self.router.route(prompt, &loads);
        let mut target = d.replica;
        if self.reps[target].dead {
            let dead = self.dead_mask();
            target = first_alive(&dead).expect("at least one live replica");
            self.reg.counter("sim.route.degraded").inc();
        }
        if d.shadow_hit > 0 {
            self.reg.counter("sim.route.affinity").inc();
        }
        self.router.note_routed(target, prompt);
        target
    }

    fn enqueue(&mut self, req: usize, replica: usize, now: u64) {
        self.st[req].phase = ReqPhase::Queued;
        self.st[req].replica = replica;
        self.st[req].mark_ns = now;
        self.reps[replica].queue.push_back(req);
        self.reps[replica].inflight += 1;
        self.queued_now += 1;
        self.reg.gauge("sim.queue.depth").set(self.queued_now as f64);
        self.admit(replica, now);
    }

    fn admit(&mut self, replica: usize, now: u64) {
        if self.reps[replica].dead {
            return;
        }
        while !self.reps[replica].queue.is_empty() && self.reps[replica].free_lanes > 0 {
            // FCFS pops the queue head; EDF scans for the earliest
            // absolute e2e deadline, breaking ties on request index
            // (submission order) so dispatch is deterministic.
            let pos = match &self.slo {
                Some(slo) if slo.edf => {
                    let q = &self.reps[replica].queue;
                    (0..q.len())
                        .min_by_key(|&i| (slo.reqs[q[i]].e2e_deadline_ns, q[i]))
                        .unwrap()
                }
                _ => 0,
            };
            let req = self.reps[replica].queue.remove(pos).unwrap();
            self.queued_now -= 1;
            self.reps[replica].free_lanes -= 1;
            self.reps[replica].running += 1;
            let wait = now - self.st[req].mark_ns;
            self.reg.histogram("sim.queue_wait_ns").record(wait as f64);

            let r = self.reqs[req];
            let covered = r.prompt_tokens.saturating_sub(PREFILL_TAIL_TOKENS);
            let (hit, cold) = if self.cfg.prefix_cache
                && self.reps[replica].cached.touch(r.prompt_id)
            {
                (covered, false)
            } else if self.cfg.prefix_cache
                && self.cfg.cold_retain_prompts > 0
                && self.reps[replica].cold.remove(r.prompt_id)
            {
                // promote-on-hit: the prompt leaves the cold set now
                // and re-enters the hot LRU at completion
                (covered, true)
            } else {
                (0, false)
            };
            let s = &mut self.st[req];
            s.phase = ReqPhase::Running;
            s.replica = replica;
            s.hit_tokens = hit;
            if hit > 0 && cold {
                self.reg.counter("sim.prefix.cold_hit_requests").inc();
                self.reg
                    .counter("sim.prefix.cold_hit_tokens")
                    .add(hit as f64);
                self.start_stage(req, Stage::ColdHit, now);
            } else if hit > 0 {
                self.reg.counter("sim.prefix.hit_requests").inc();
                self.reg.counter("sim.prefix.hit_tokens").add(hit as f64);
                self.reg
                    .counter("sim.dequant.bytes")
                    .add((hit as u64 * self.cfg.cost.kv_bytes_per_token) as f64);
                self.start_stage(req, Stage::Dequant, now);
            } else {
                self.start_stage(req, Stage::Prefill, now);
            }
        }
    }

    fn stage_duration(&self, req: usize, stage: Stage) -> u64 {
        let r = &self.reqs[req];
        let c = &self.cfg.cost;
        let hit = self.st[req].hit_tokens;
        match stage {
            Stage::Dequant => hit as u64 * c.dequant_ns,
            Stage::ColdHit => hit as u64 * c.cold_hit_ns,
            Stage::Prefill => (r.prompt_tokens - hit) as u64 * c.prefill_ns,
            Stage::FirstToken => c.decode_ns,
            Stage::Decode => (r.gen_tokens - 1) as u64 * c.decode_ns,
        }
    }

    fn start_stage(&mut self, req: usize, stage: Stage, now: u64) {
        self.st[req].mark_ns = now;
        let dur = self.stage_duration(req, stage);
        self.push(now + dur, EvKind::StageDone { req, stage });
    }

    fn on_stage_done(&mut self, req: usize, stage: Stage, now: u64) {
        if self.st[req].phase != ReqPhase::Running {
            return; // stale event: the replica died mid-service
        }
        let replica = self.st[req].replica;
        let start = self.st[req].mark_ns;
        self.reps[replica].busy_ns += now - start;
        if self.cfg.record_trace {
            self.trace.push(StageSpan {
                req,
                replica,
                stage,
                start_ns: start,
                end_ns: now,
            });
        }
        match stage {
            Stage::Dequant => {
                self.reg
                    .histogram("sim.stage.dequant_ns")
                    .record((now - start) as f64);
                self.start_stage(req, Stage::Prefill, now);
            }
            Stage::ColdHit => {
                self.reg
                    .histogram("sim.stage.cold_hit_ns")
                    .record((now - start) as f64);
                self.start_stage(req, Stage::Prefill, now);
            }
            Stage::Prefill => {
                self.reg
                    .histogram("sim.stage.prefill_ns")
                    .record((now - start) as f64);
                self.start_stage(req, Stage::FirstToken, now);
            }
            Stage::FirstToken => {
                let ttft = now - self.reqs[req].arrival_ns;
                self.reg.histogram("sim.ttft_ns").record(ttft as f64);
                if let Some(slo) = self.slo.as_mut() {
                    if now > slo.reqs[req].ttft_deadline_ns {
                        self.reg.counter("serve.slo_ttft_miss").inc();
                        if self.cfg.record_trace {
                            slo.push_event(
                                now,
                                TraceEvent::DeadlineMiss {
                                    req: req as u64,
                                    kind: "ttft",
                                },
                            );
                        }
                    }
                }
                if self.reqs[req].gen_tokens > 1 {
                    self.start_stage(req, Stage::Decode, now);
                } else {
                    self.complete(req, now);
                }
            }
            Stage::Decode => self.complete(req, now),
        }
    }

    fn complete(&mut self, req: usize, now: u64) {
        let replica = self.st[req].replica;
        self.st[req].phase = ReqPhase::Done;
        self.free_lane(replica);
        self.reg
            .histogram("sim.stage.decode_ns")
            .record((self.reqs[req].gen_tokens as u64 * self.cfg.cost.decode_ns) as f64);
        self.reg
            .histogram("sim.latency_ns")
            .record((now - self.reqs[req].arrival_ns) as f64);
        self.reg.counter("sim.completed").inc();
        self.gen_total += self.reqs[req].gen_tokens as u64;
        if let Some(slo) = self.slo.as_mut() {
            if now > slo.reqs[req].e2e_deadline_ns {
                self.reg.counter("serve.slo_deadline_miss").inc();
                if self.cfg.record_trace {
                    slo.push_event(
                        now,
                        TraceEvent::DeadlineMiss {
                            req: req as u64,
                            kind: "e2e",
                        },
                    );
                }
            } else {
                let tokens = self.reqs[req].gen_tokens as u64;
                slo.goodput_tokens += tokens;
                self.reg
                    .counter("serve.slo_goodput_tokens")
                    .add(tokens as f64);
            }
        }
        self.settled += 1;
        self.last_completion_ns = self.last_completion_ns.max(now);
        if self.cfg.record_trace {
            self.completions.push((now, req));
        }
        if self.cfg.prefix_cache {
            let evicted = self.reps[replica]
                .cached
                .insert(self.reqs[req].prompt_id);
            // demote-on-evict: the hot LRU's victim falls into the
            // cold tier instead of being forgotten (the cold set's own
            // LRU victim, if any, is gone for good)
            if self.cfg.cold_retain_prompts > 0 {
                if let Some(ev) = evicted {
                    let _ = self.reps[replica].cold.insert(ev);
                }
            }
        }
        self.admit(replica, now);
    }

    fn free_lane(&mut self, replica: usize) {
        let rep = &mut self.reps[replica];
        rep.running -= 1;
        rep.inflight -= 1;
        rep.free_lanes += 1;
    }

    fn on_arrive(&mut self, req: usize, now: u64) {
        self.reg.counter("sim.requests").inc();
        self.reg
            .counter("sim.tokens.prompt")
            .add(self.reqs[req].prompt_tokens as f64);
        if self.slo_reject(req, now) {
            return; // turned away at the door: never routed
        }
        let target = self.pick_target(req);
        self.enqueue(req, target, now);
    }

    /// SLO gate at arrival: stamp the assignment event, run the
    /// admission controller, and settle rejected requests without
    /// routing them. Returns `true` when the request was rejected.
    fn slo_reject(&mut self, req: usize, now: u64) -> bool {
        let Some(slo) = self.slo.as_mut() else {
            return false;
        };
        let s = slo.reqs[req];
        if self.cfg.record_trace {
            slo.push_event(
                now,
                TraceEvent::SloAssigned {
                    req: req as u64,
                    tier: s.tier.name(),
                    ttft_deadline_ns: s.ttft_deadline_ns,
                    e2e_deadline_ns: s.e2e_deadline_ns,
                },
            );
        }
        let decision = match slo.admission.as_mut() {
            Some(ctl) => ctl.offer(now, s.sim.prompt_tokens, s.sim.gen_tokens),
            None => AdmissionDecision::Accept,
        };
        match decision {
            AdmissionDecision::Accept => {
                self.reg.counter("serve.slo_accepted").inc();
                false
            }
            AdmissionDecision::Queue => {
                self.reg.counter("serve.slo_queued").inc();
                false
            }
            AdmissionDecision::Reject => {
                self.reg.counter("serve.slo_rejected").inc();
                if self.cfg.record_trace {
                    slo.push_event(now, TraceEvent::Rejected { req: req as u64 });
                }
                self.st[req].phase = ReqPhase::Rejected;
                self.settled += 1;
                true
            }
        }
    }

    fn on_transfer_done(&mut self, req: usize, to: usize, now: u64) {
        let target = if self.reps[to].dead {
            self.pick_target(req)
        } else {
            // migrate affinity with the request, as the cluster's
            // requeue path does
            self.router
                .note_routed(to, &self.prompts[self.reqs[req].prompt_id]);
            to
        };
        self.enqueue(req, target, now);
    }

    fn on_steal_scan(&mut self, now: u64) {
        self.reg.counter("sim.steal.scans").inc();
        if self.settled >= self.reqs.len() {
            return; // drained: let the event heap empty out
        }
        let mut loads = self.loads();
        let dead = self.dead_mask();
        mask_dead(&mut loads, &dead);
        if let Some(plan) = self.router.steal_plan(&loads) {
            self.reg.counter("sim.steal.plans").inc();
            let n = plan.max_requests.min(self.reps[plan.from].queue.len());
            for _ in 0..n {
                // youngest-first, queued-only: the drain_queued contract
                let req = self.reps[plan.from].queue.pop_back().unwrap();
                self.reps[plan.from].inflight -= 1;
                self.st[req].phase = ReqPhase::InTransfer;
                self.stolen += 1;
                self.reg.counter("sim.steal.stolen").inc();
                self.push(
                    now + self.cfg.cost.transfer_ns,
                    EvKind::TransferDone { req, to: plan.to },
                );
            }
        }
        self.push(now + self.cfg.steal_interval_ns, EvKind::StealScan);
    }

    fn on_fail(&mut self, replica: usize, now: u64) {
        if self.reps[replica].dead {
            return;
        }
        self.reps[replica].dead = true;
        self.reg.counter("sim.replica.deaths").inc();
        // queued work re-routes (sequentially, like the cluster's
        // requeue path — loads refresh between decisions)
        let queued: Vec<usize> = self.reps[replica].queue.drain(..).collect();
        self.reps[replica].inflight -= queued.len();
        for req in queued {
            self.reg.counter("sim.route.rerouted_dead").inc();
            let target = self.pick_target(req);
            self.enqueue(req, target, now);
        }
        // running work is answered-with-error
        for req in 0..self.st.len() {
            if self.st[req].phase == ReqPhase::Running && self.st[req].replica == replica {
                self.st[req].phase = ReqPhase::Failed;
                self.reg.counter("sim.failed").inc();
                self.settled += 1;
            }
        }
    }

    fn run(mut self) -> SimReport {
        for (i, r) in self.reqs.iter().enumerate() {
            self.push(r.arrival_ns, EvKind::Arrive { req: i });
        }
        if self.cfg.steal {
            let first = self.reqs.first().map(|r| r.arrival_ns).unwrap_or(0);
            self.push(first + self.cfg.steal_interval_ns, EvKind::StealScan);
        }
        if let Some(f) = self.cfg.failure {
            assert!(f.replica < self.cfg.replicas);
            self.push(f.at_ns, EvKind::Fail { replica: f.replica });
        }
        while let Some(Reverse(ev)) = self.heap.pop() {
            match ev.kind {
                EvKind::Arrive { req } => self.on_arrive(req, ev.ns),
                EvKind::StageDone { req, stage } => self.on_stage_done(req, stage, ev.ns),
                EvKind::TransferDone { req, to } => self.on_transfer_done(req, to, ev.ns),
                EvKind::StealScan => self.on_steal_scan(ev.ns),
                EvKind::Fail { replica } => self.on_fail(replica, ev.ns),
            }
        }
        assert_eq!(self.settled, self.reqs.len(), "every request settles");

        let span_ns = self.last_completion_ns;
        let busy: u64 = self.reps.iter().map(|r| r.busy_ns).sum();
        let capacity = span_ns as f64 * (self.cfg.replicas * self.cfg.lanes) as f64;
        let utilization = if capacity > 0.0 {
            busy as f64 / capacity
        } else {
            0.0
        };
        let tokens_per_s = if span_ns > 0 {
            self.gen_total as f64 / (span_ns as f64 / 1e9)
        } else {
            0.0
        };
        let failed = self.reg.counter("sim.failed").get() as usize;
        let completed = self.reg.counter("sim.completed").get() as usize;
        self.reg.counter("sim.tokens.gen").add(self.gen_total as f64);
        self.reg
            .gauge("sim.lane_utilization_pct")
            .set(utilization * 100.0);
        let h = self.reg.histogram("sim.ttft_ns");
        let (p50, p99, p999) = (
            h.percentile(50.0),
            h.percentile(99.0),
            h.percentile(99.9),
        );
        let slo_goodput_tokens_per_s = match &self.slo {
            Some(slo) if span_ns > 0 => slo.goodput_tokens as f64 / (span_ns as f64 / 1e9),
            _ => 0.0,
        };
        let slo_events = self.slo.map(|s| s.events).unwrap_or_default();
        SimReport {
            label: self.cfg.label(),
            requests: self.reqs.len(),
            completed,
            failed,
            stolen: self.stolen,
            gen_tokens: self.gen_total,
            span_ns,
            ttft_p50_ns: p50,
            ttft_p99_ns: p99,
            ttft_p999_ns: p999,
            tokens_per_s,
            utilization,
            registry: self.reg,
            completions: self.completions,
            trace: self.trace,
            slo_goodput_tokens_per_s,
            slo_events,
        }
    }
}

fn build_sim<'a>(cfg: &'a TimeflowConfig, reqs: &'a [SimRequest], slo: Option<SloCtx>) -> Sim<'a> {
    assert!(cfg.replicas > 0 && cfg.lanes > 0);
    assert!(!reqs.is_empty(), "empty workload");
    let max_pid = reqs.iter().map(|r| r.prompt_id).max().unwrap_or(0);
    Sim {
        cfg,
        reqs,
        slo,
        prompts: (0..=max_pid).map(synth_prompt).collect(),
        router: Router::new(cfg.replicas, cfg.routing),
        reps: (0..cfg.replicas)
            .map(|_| Rep::new(cfg.lanes, cfg.retain_prompts, cfg.cold_retain_prompts))
            .collect(),
        st: vec![
            ReqState {
                phase: ReqPhase::Pending,
                replica: 0,
                hit_tokens: 0,
                mark_ns: 0,
            };
            reqs.len()
        ],
        heap: BinaryHeap::new(),
        seq: 0,
        settled: 0,
        queued_now: 0,
        reg: Registry::default(),
        completions: Vec::new(),
        trace: Vec::new(),
        last_completion_ns: 0,
        stolen: 0,
        gen_total: 0,
    }
}

/// Simulate a pre-generated request list under `cfg`.
pub fn simulate_requests(cfg: &TimeflowConfig, reqs: &[SimRequest]) -> SimReport {
    build_sim(cfg, reqs, None).run()
}

/// Simulate a deadline-stamped request list under `cfg` with the SLO
/// machinery engaged per `policy`: EDF dispatch (vs FCFS), byte-budget
/// admission against `policy.capacity_bytes`, TTFT/e2e deadline
/// accounting into `serve.slo_*` counters, and goodput-under-SLO in
/// the report. The hyper-scaling dividend is visible here: a q4 cost
/// model admits strictly more load than f32 at the same byte capacity.
pub fn simulate_slo(cfg: &TimeflowConfig, reqs: &[SloRequest], policy: &SloPolicy) -> SimReport {
    let sims: Vec<SimRequest> = reqs.iter().map(|r| r.sim).collect();
    let admission = if policy.admission {
        Some(AdmissionController::new(policy.capacity_bytes, cfg.cost))
    } else {
        None
    };
    let ctx = SloCtx {
        reqs: reqs.to_vec(),
        edf: policy.edf,
        admission,
        goodput_tokens: 0,
        events: Vec::new(),
    };
    build_sim(cfg, &sims, Some(ctx)).run()
}

/// Generate `spec`'s workload and simulate it under `cfg`.
pub fn simulate(cfg: &TimeflowConfig, spec: &WorkloadSpec) -> SimReport {
    let reqs = generate_workload(spec);
    simulate_requests(cfg, &reqs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(replicas: usize, lanes: usize) -> TimeflowConfig {
        let mut cfg = TimeflowConfig::new(replicas, lanes, RoutingPolicy::Prefix);
        cfg.record_trace = true;
        cfg
    }

    #[test]
    fn cost_model_orders_dtypes() {
        let f32c = CostModel::default_for(KvDtype::F32, AllocatorKind::Uniform);
        let q8 = CostModel::default_for(KvDtype::Q8, AllocatorKind::Uniform);
        let q4 = CostModel::default_for(KvDtype::Q4, AllocatorKind::Uniform);
        // cheaper KV payloads mean cheaper memory-bound decode ...
        assert!(f32c.decode_ns > q8.decode_ns);
        assert!(q8.decode_ns > q4.decode_ns);
        // ... and fewer dequant bytes (despite the dequant-throughput
        // surcharge, the byte count dominates)
        assert!(f32c.kv_bytes_per_token > q8.kv_bytes_per_token);
        assert!(q8.kv_bytes_per_token > q4.kv_bytes_per_token);
        assert!(f32c.prefill_ns > 0 && f32c.decode_ns > 0);
    }

    #[test]
    fn budget_conserving_allocators_price_identically() {
        // kv_latency_fraction_planned's documented property carries
        // over: the plan total, not its shape, sets decode cost.
        let u = CostModel::default_for(KvDtype::Q8, AllocatorKind::Uniform);
        let p = CostModel::default_for(KvDtype::Q8, AllocatorKind::Pyramid);
        assert_eq!(u.decode_ns, p.decode_ns);
    }

    #[test]
    fn single_request_ttft_is_exact() {
        let mut cfg = base_cfg(1, 1);
        cfg.steal = false;
        cfg.prefix_cache = false;
        let reqs = [SimRequest {
            arrival_ns: 1000,
            prompt_id: 0,
            prompt_tokens: 40,
            gen_tokens: 4,
        }];
        let rep = simulate_requests(&cfg, &reqs);
        let expect_ttft = 40 * cfg.cost.prefill_ns + cfg.cost.decode_ns;
        assert_eq!(rep.ttft_p50_ns, expect_ttft as f64);
        assert_eq!(rep.completed, 1);
        assert_eq!(
            rep.span_ns,
            1000 + expect_ttft + 3 * cfg.cost.decode_ns
        );
        assert_eq!(rep.gen_tokens, 4);
        // one lane, fully busy from admission to completion
        assert!((rep.utilization - (rep.span_ns - 1000) as f64 / rep.span_ns as f64).abs() < 1e-12);
    }

    #[test]
    fn prefix_hit_trades_prefill_for_dequant() {
        let mut cfg = base_cfg(1, 1).with_kv(KvDtype::Q8, AllocatorKind::Uniform);
        cfg.record_trace = true;
        cfg.steal = false;
        let r = SimRequest {
            arrival_ns: 0,
            prompt_id: 3,
            prompt_tokens: 80,
            gen_tokens: 2,
        };
        let mut second = r;
        second.arrival_ns = 10_000_000_000; // long after the first completes
        let rep = simulate_requests(&cfg, &[r, second]);
        assert_eq!(rep.completed, 2);
        let hits = rep.registry.counters["sim.prefix.hit_requests"].get();
        assert_eq!(hits, 1.0, "second request hits the retained prefix");
        let dequants: Vec<_> = rep
            .trace
            .iter()
            .filter(|s| s.stage == Stage::Dequant)
            .collect();
        assert_eq!(dequants.len(), 1);
        let hit_tokens = (80 - PREFILL_TAIL_TOKENS) as u64;
        assert_eq!(
            dequants[0].end_ns - dequants[0].start_ns,
            hit_tokens * cfg.cost.dequant_ns
        );
        // the hit's prefill span only covers the uncached tail
        let prefills: Vec<u64> = rep
            .trace
            .iter()
            .filter(|s| s.stage == Stage::Prefill)
            .map(|s| s.end_ns - s.start_ns)
            .collect();
        assert_eq!(prefills[0], 80 * cfg.cost.prefill_ns);
        assert_eq!(
            prefills[1],
            PREFILL_TAIL_TOKENS as u64 * cfg.cost.prefill_ns
        );
    }

    #[test]
    fn cold_hit_prices_promote_not_prefill() {
        // hot LRU of 1 prompt + cold tier: A runs, B evicts A into the
        // cold set, A returns as a cold hit priced at cold_hit_ns.
        let mut cfg = base_cfg(1, 1);
        cfg.steal = false;
        cfg.retain_prompts = 1;
        cfg.cold_retain_prompts = 4;
        let gap = 10_000_000_000u64; // each request runs alone
        let mk = |i: u64, pid: usize| SimRequest {
            arrival_ns: i * gap,
            prompt_id: pid,
            prompt_tokens: 80,
            gen_tokens: 2,
        };
        let mut rep = simulate_requests(&cfg, &[mk(0, 0), mk(1, 1), mk(2, 0)]);
        assert_eq!(rep.completed, 3);
        assert_eq!(
            rep.registry.counter("sim.prefix.cold_hit_requests").get(),
            1.0,
            "A's return is a cold hit"
        );
        let hit_tokens = (80 - PREFILL_TAIL_TOKENS) as u64;
        assert_eq!(
            rep.registry.counter("sim.prefix.cold_hit_tokens").get(),
            hit_tokens as f64
        );
        // hot-hit counters untouched: the only hits were cold
        assert_eq!(rep.registry.counter("sim.prefix.hit_requests").get(), 0.0);
        let colds: Vec<_> = rep
            .trace
            .iter()
            .filter(|s| s.stage == Stage::ColdHit)
            .collect();
        assert_eq!(colds.len(), 1);
        assert_eq!(
            colds[0].end_ns - colds[0].start_ns,
            hit_tokens * cfg.cost.cold_hit_ns
        );
        // the promote is strictly cheaper than the prefill it replaced
        assert!(cfg.cost.cold_hit_ns < cfg.cost.prefill_ns);
        // cold-enabled runs stay bit-identical under the same seed
        // (the property the CI trace-determinism leg gates)
        let spec = WorkloadSpec::new(256, 0xC01D);
        let mut c2 = base_cfg(2, 2);
        c2.retain_prompts = 4;
        c2.cold_retain_prompts = 16;
        let mut a = simulate(&c2, &spec);
        let b = simulate(&c2, &spec);
        assert_eq!(a.chrome_trace_json(), b.chrome_trace_json());
        assert!(a.registry.counter("sim.prefix.cold_hit_requests").get() > 0.0);
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let cfg = base_cfg(4, 2);
        let spec = WorkloadSpec::new(512, 0xFEED);
        let a = simulate(&cfg, &spec);
        let b = simulate(&cfg, &spec);
        assert_eq!(a.completions, b.completions);
        assert_eq!(
            a.registry.histogram_samples("sim.ttft_ns"),
            b.registry.histogram_samples("sim.ttft_ns")
        );
        assert_eq!(a.ttft_p999_ns.to_bits(), b.ttft_p999_ns.to_bits());
        assert_eq!(a.tokens_per_s.to_bits(), b.tokens_per_s.to_bits());
        assert_eq!(
            a.chrome_trace_json(),
            b.chrome_trace_json(),
            "trace dump is byte-identical under the same seed"
        );
    }

    #[test]
    fn trace_export_renders_stage_spans() {
        let cfg = base_cfg(2, 1);
        let spec = WorkloadSpec::new(64, 9);
        let rep = simulate(&cfg, &spec);
        assert!(!rep.trace.is_empty());
        let groups = rep.trace_events();
        let total: usize = groups.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, rep.trace.len());
        let j = crate::util::Json::parse(&rep.chrome_trace_json()).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // every stage span renders as one "X" complete event
        assert_eq!(evs.len(), rep.trace.len());
        assert!(evs
            .iter()
            .all(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")));
    }

    #[test]
    fn steal_drains_hot_replica_to_idle_one() {
        // Prefix routing + one shared prompt piles everything on one
        // replica; stealing must move queued work to the idle one and
        // finish strictly earlier.
        let mk = |steal: bool| {
            let mut cfg = base_cfg(2, 1);
            cfg.steal = steal;
            cfg.prefix_cache = false;
            let mut spec = WorkloadSpec::new(64, 7);
            spec.arrival = Arrival::Uniform;
            spec.mean_gap_ns = 1000; // near-simultaneous
            spec.n_prompts = 1;
            simulate(&cfg, &spec)
        };
        let with = mk(true);
        let without = mk(false);
        assert!(with.stolen > 0, "steals expected");
        assert_eq!(without.stolen, 0);
        assert!(with.span_ns < without.span_ns);
        assert_eq!(with.completed, 64);
    }

    #[test]
    fn replica_death_conserves_requests() {
        let mut cfg = base_cfg(3, 1);
        cfg.failure = Some(ReplicaFailure {
            replica: 0,
            at_ns: 3_000_000,
        });
        let mut spec = WorkloadSpec::new(96, 11);
        spec.arrival = Arrival::Bursty;
        let rep = simulate(&cfg, &spec);
        assert_eq!(rep.completed + rep.failed, 96, "no loss, no duplication");
        assert!(rep.failed <= cfg.lanes, "only running work can fail");
        // every completion is unique
        let mut ids: Vec<usize> = rep.completions.iter().map(|&(_, r)| r).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), rep.completed);
    }

    #[test]
    fn stage_order_is_respected_per_request() {
        let cfg = base_cfg(2, 2);
        let spec = WorkloadSpec::new(128, 3);
        let rep = simulate(&cfg, &spec);
        let mut per_req: HashMap<usize, Vec<&StageSpan>> = HashMap::new();
        for s in &rep.trace {
            per_req.entry(s.req).or_default().push(s);
        }
        for (req, spans) in per_req {
            for w in spans.windows(2) {
                assert!(
                    w[0].end_ns <= w[1].start_ns && w[0].stage < w[1].stage,
                    "req {req}: stage {:?} overlaps {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }

    #[test]
    fn workload_generation_is_deterministic_and_in_range() {
        let spec = WorkloadSpec::new(1000, 42);
        let a = generate_workload(&spec);
        let b = generate_workload(&spec);
        assert_eq!(a.len(), 1000);
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| (x.arrival_ns, x.prompt_id, x.prompt_tokens, x.gen_tokens)
                == (y.arrival_ns, y.prompt_id, y.prompt_tokens, y.gen_tokens)));
        for r in &a {
            assert!(r.prompt_id < spec.n_prompts);
            assert!((spec.prompt_tokens.0..=spec.prompt_tokens.1).contains(&r.prompt_tokens));
            assert!((spec.gen_tokens.0..=spec.gen_tokens.1).contains(&r.gen_tokens));
        }
        // arrivals are non-decreasing
        assert!(a.windows(2).all(|w| w[0].arrival_ns <= w[1].arrival_ns));
        // zipf skew: the head prompt is the most common
        let count = |pid: usize| a.iter().filter(|r| r.prompt_id == pid).count();
        assert!(count(0) > count(spec.n_prompts - 1));
    }

    #[test]
    fn edf_dispatch_reorders_queue_by_deadline() {
        use crate::engine::slo::{SloPolicy, SloTier};
        let mut cfg = base_cfg(1, 1);
        cfg.steal = false;
        cfg.prefix_cache = false;
        // req 0 takes the only lane; 1 (batch) and 2 (interactive)
        // queue behind it while it runs.
        let reqs = [
            SloRequest::stamp(
                SimRequest {
                    arrival_ns: 0,
                    prompt_id: 0,
                    prompt_tokens: 32,
                    gen_tokens: 4,
                },
                SloTier::Standard,
            ),
            SloRequest::stamp(
                SimRequest {
                    arrival_ns: 1000,
                    prompt_id: 1,
                    prompt_tokens: 32,
                    gen_tokens: 4,
                },
                SloTier::Batch,
            ),
            SloRequest::stamp(
                SimRequest {
                    arrival_ns: 2000,
                    prompt_id: 2,
                    prompt_tokens: 32,
                    gen_tokens: 4,
                },
                SloTier::Interactive,
            ),
        ];
        let edf = simulate_slo(&cfg, &reqs, &SloPolicy::edf_admitted(1, 1));
        let fcfs = simulate_slo(&cfg, &reqs, &SloPolicy::fcfs_open(1, 1));
        let order = |r: &SimReport| r.completions.iter().map(|&(_, q)| q).collect::<Vec<_>>();
        assert_eq!(order(&edf), vec![0, 2, 1], "EDF jumps the interactive request");
        assert_eq!(order(&fcfs), vec![0, 1, 2], "FCFS keeps arrival order");
        assert!(edf.slo_goodput_tokens_per_s > 0.0);
    }

    #[test]
    fn admission_rejects_settle_and_counters_conserve() {
        use crate::engine::slo::SloPolicy;
        let mut cfg = base_cfg(1, 1);
        cfg.steal = false;
        cfg.prefix_cache = false;
        let demand = 48 * cfg.cost.kv_bytes_per_token; // (32 + 16) tokens
        let policy = SloPolicy {
            edf: true,
            admission: true,
            capacity_bytes: 2 * demand,
        };
        let reqs: Vec<SloRequest> = (0..8)
            .map(|i| {
                SloRequest::stamp(
                    SimRequest {
                        arrival_ns: 0,
                        prompt_id: i,
                        prompt_tokens: 32,
                        gen_tokens: 16,
                    },
                    crate::engine::slo::SloTier::Standard,
                )
            })
            .collect();
        let mut rep = simulate_slo(&cfg, &reqs, &policy);
        let accepted = rep.registry.counter("serve.slo_accepted").get();
        let queued = rep.registry.counter("serve.slo_queued").get();
        let rejected = rep.registry.counter("serve.slo_rejected").get();
        assert_eq!(accepted + queued + rejected, 8.0, "admission conserves");
        assert_eq!((accepted, queued, rejected), (2.0, 2.0, 4.0));
        assert_eq!(rep.completed as f64, accepted + queued, "rejects never run");
        // 8 SloAssigned + 4 Rejected, no deadline misses uncontended
        assert_eq!(rep.slo_events.len(), 12);
        assert_eq!(rep.registry.counter("serve.slo_deadline_miss").get(), 0.0);
        assert_eq!(rep.registry.counter("serve.slo_ttft_miss").get(), 0.0);
        let b = simulate_slo(&cfg, &reqs, &policy);
        assert_eq!(
            rep.chrome_trace_json(),
            b.chrome_trace_json(),
            "SLO trace dump is byte-identical under the same inputs"
        );
    }
}
