#!/usr/bin/env python3
"""Analytically seed tools/bench_baselines/BENCH_sim.json for bench_sim.

`bench_sim --smoke` is a perf *model*, not a wall-clock bench: every
gated number is a pure function of the workload seed and the priced
cost model (rust/src/engine/timeflow.rs). This script re-derives the
pinnable subset bit-for-bit in Python:

* ``cost.*`` / ``alloc.*`` — the App. G latency model (analysis/
  latency_model.rs) priced exactly as ``CostModel::price``: IEEE-754
  f64 arithmetic in the same operation order, ``to_ns`` rounding
  half-away-from-zero (Python ``math.floor(x + 0.5)``, *not* Python's
  banker's ``round``), budget-conserving allocator plans (the Rust
  test ``budget_conserving_allocators_price_identically`` pins the
  conservation this relies on);
* ``uncontended.*`` — the closed-form scenario: round-robin over
  4x1 lanes with 20 ms uniform gaps above worst-case service, so no
  request ever queues and TTFT_i = prompt_i * prefill_ns + decode_ns.
  The workload draws mirror ``generate_workload`` (SplitMix64 stream:
  uniform arrivals consume no draw; prompt id via ``weighted`` over
  zipf(1.0) weights; gen tokens via ``below``) and the percentile is
  ``Histogram::percentile`` (sorted samples, half-up index — 2048
  samples sit below the 16384 reservoir cap, so the full stream is
  retained);
* ``workload.grid.*`` — integer draw totals of the contended Poisson
  workload. Gap *values* go through libm ``ln`` (not bit-pinned across
  platforms) but each gap consumes exactly one ``f64()`` draw, and the
  gated totals depend only on stream position — so they are exact;
* ``fail.settled`` — conservation: every request settles;
* contended ``grid.*`` cells and the ``fail.*`` split are emitted as
  null (structural gate: must exist + be numeric). Refresh them from
  the BENCH_sim.json artifact uploaded by the CI ``sim-gate`` job to
  activate value gating (tools/bench_compare.py treats null as
  presence-only; zero-valued metrics then gate exactly — see
  ``--zero-tolerance``).

Usage: python3 tools/seed_bench_sim.py [--out tools/bench_baselines/BENCH_sim.json]

Without --out the baseline JSON is printed to stdout.
"""

import argparse
import json
import math
import sys

M64 = (1 << 64) - 1

# -- rust/src/util/rng.rs ---------------------------------------------------


class SplitMix64:
    def __init__(self, seed: int) -> None:
        self.state = seed & M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return (z ^ (z >> 31)) & M64

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def weighted(self, weights: list) -> int:
        total = 0.0
        for w in weights:
            total += w
        x = self.f64() * total
        for i, w in enumerate(weights):
            x -= w
            if x <= 0.0:
                return i
        return len(weights) - 1


def round_half_up(x: float) -> int:
    """Rust ``f64::round`` (half away from zero) for non-negative x."""
    assert x >= 0.0
    return math.floor(x + 0.5)


def to_ns(seconds: float) -> int:
    return round_half_up(seconds * 1e9)


# -- rust/src/analysis/latency_model.rs (Llama 3.1 8B on H100) --------------

N_LAYERS = 32.0
D_MODEL = 4096.0
D_FF = 14336.0
D_KV = 1024.0
VOCAB = 128256.0
W_BYTES = 2.0  # weight/activation bytes per element (bf16)

FLOPS_PER_S = 989.5e12  # H100 SXM bf16 dense
BYTES_PER_S = 3.35e12  # H100 HBM

# -- rust/src/engine/timeflow.rs pricing constants --------------------------

HEAD_DIM = 64
REF_BATCH = 64.0
REF_SEQ = 4096.0
REF_CR = 4.0
UPLOAD_BYTES_PER_S = 64e9
DEQUANT_BYTES_PER_S = 8e9


def row_payload_bytes(dtype: str, row_len: int) -> int:
    """KvDtype::row_payload_bytes (kvcache/quant.rs)."""
    if dtype == "f32":
        return row_len * 4
    codes = row_len if dtype == "q8" else (row_len + 1) // 2
    return codes + 5  # codes + f32 scale + u8 zero-point


def flops(batch: float, seq: float) -> float:
    per_layer = (
        6.0 * D_MODEL * D_FF
        + 4.0 * D_MODEL * D_MODEL
        + 4.0 * D_MODEL * D_KV
        + 4.0 * D_MODEL * seq
    )
    return N_LAYERS * batch * per_layer + 2.0 * batch * D_MODEL * VOCAB


def kv_reads(kv_bytes: float, batch: float, seq: float) -> float:
    return N_LAYERS * 2.0 * batch * seq * D_KV * kv_bytes


def reads(kv_bytes: float, batch: float, seq: float) -> float:
    params_per_layer = (
        3.0 * D_MODEL * D_FF + 2.0 * D_MODEL * D_MODEL + 2.0 * D_MODEL * D_KV
    )
    return (N_LAYERS * params_per_layer + D_MODEL * VOCAB) * W_BYTES + kv_reads(
        kv_bytes, batch, seq
    )


def cost_model(dtype: str) -> dict:
    """CostModel::price for Llama 3.1 8B / H100, any conserving allocator."""
    kv_bytes = row_payload_bytes(dtype, HEAD_DIM) / float(HEAD_DIM)
    prefill_s = flops(1.0, REF_SEQ) / FLOPS_PER_S

    layers = int(N_LAYERS)
    kv_heads = max(int(D_KV) // HEAD_DIM, 1)
    cells = float(layers * kv_heads)
    glob = int((REF_SEQ / REF_CR) * cells)
    # budget-conserving plans: total == global exactly (uniform divides
    # evenly here; pyramid/adaptive apportion the same total)
    eff_seq = min(glob / cells, REF_SEQ)
    t_compute = flops(REF_BATCH, REF_SEQ) / FLOPS_PER_S
    t_memory = (
        reads(kv_bytes, REF_BATCH, 0.0) + kv_reads(kv_bytes, REF_BATCH, eff_seq)
    ) / BYTES_PER_S
    decode_s = max(t_compute, t_memory) / REF_BATCH

    rows_per_token = N_LAYERS * (D_KV / float(HEAD_DIM)) * 2.0
    bytes_per_token = rows_per_token * float(row_payload_bytes(dtype, HEAD_DIM))
    dequant_s = bytes_per_token / UPLOAD_BYTES_PER_S
    if dtype != "f32":
        dequant_s += bytes_per_token / DEQUANT_BYTES_PER_S

    # cold promotion is always priced at the cold tier's q4 storage
    # dtype (upload + dequant of the q4 lattice), independent of the
    # hot pool dtype — one constant across the sweep
    cold_bytes_per_token = rows_per_token * float(row_payload_bytes("q4", HEAD_DIM))
    cold_hit_s = (
        cold_bytes_per_token / UPLOAD_BYTES_PER_S
        + cold_bytes_per_token / DEQUANT_BYTES_PER_S
    )

    return {
        "prefill_ns": max(to_ns(prefill_s), 1),
        "decode_ns": max(to_ns(decode_s), 1),
        "dequant_ns": max(to_ns(dequant_s), 1),
        "cold_hit_ns": max(to_ns(cold_hit_s), 1),
    }


# -- rust/src/engine/timeflow.rs generate_workload --------------------------

SEED = 0x51D_CAFE  # benches/bench_sim.rs SEED
N_PROMPTS = 64
PROMPT_TOKENS = (32, 96)  # inclusive
GEN_TOKENS = (16, 64)  # inclusive


def zipf_weights(n: int, s: float) -> list:
    return [1.0 / k if s == 1.0 else float(k) ** (-s) for k in range(1, n + 1)]


def generate_workload(requests: int, arrival: str, mean_gap_ns: int) -> list:
    """Mirror of generate_workload: (arrival_ns, prompt_id, prompt, gen).

    Draw order per request is (gap, prompt id, gen tokens); uniform
    arrivals consume no gap draw.
    """
    rng = SplitMix64(SEED)
    weights = zipf_weights(N_PROMPTS, 1.0)
    p_span = PROMPT_TOKENS[1] - PROMPT_TOKENS[0] + 1
    g_span = GEN_TOKENS[1] - GEN_TOKENS[0] + 1
    t = 0
    out = []
    for _ in range(requests):
        if arrival == "uniform":
            t += mean_gap_ns
        elif arrival == "poisson":
            u = rng.f64()
            # libm ln is not bit-pinned across platforms, but the gap
            # consumes exactly one draw either way; gated totals depend
            # only on stream position, never on gap values
            t += round_half_up(-math.log(1.0 - u) * float(mean_gap_ns))
        else:
            raise ValueError(arrival)
        pid = rng.weighted(weights)
        prompt = PROMPT_TOKENS[0] + (pid * 37) % p_span
        gen = GEN_TOKENS[0] + rng.below(g_span)
        out.append((t, pid, prompt, gen))
    return out


def percentile(samples: list, p: float) -> float:
    """Histogram::percentile (metrics/mod.rs): sorted, half-up index."""
    s = sorted(samples)
    idx = round_half_up((p / 100.0) * (len(s) - 1))
    return s[min(idx, len(s) - 1)]


# -- scenarios (benches/bench_sim.rs) ---------------------------------------


def uncontended(cost: dict) -> dict:
    """4 replicas x 1 lane, 20 ms uniform gaps, no prefix cache.

    Worst-case service (96-token prompt, 64 gen tokens) is ~11.3 ms,
    under the 20 ms global inter-arrival gap — so even if every request
    landed on one replica, the lane is free before the next arrival:
    zero queueing under *any* routing, and the stage pipeline is
    closed-form: TTFT = prompt * prefill + first decode; completion =
    arrival + prompt * prefill + gen * decode.
    """
    work = generate_workload(2048, "uniform", 20_000_000)
    worst = (
        PROMPT_TOKENS[1] * cost["prefill_ns"] + GEN_TOKENS[1] * cost["decode_ns"]
    )
    assert worst < 20_000_000, "scenario must stay uncontended"
    ttfts = []
    gen_total = 0
    span = 0
    for arrival, _pid, prompt, gen in work:
        ttfts.append(float(prompt * cost["prefill_ns"] + cost["decode_ns"]))
        gen_total += gen
        span = max(span, arrival + prompt * cost["prefill_ns"] + gen * cost["decode_ns"])
    return {
        "completed": len(work),
        "gen_tokens": gen_total,
        "ttft_p50_ns": percentile(ttfts, 50.0),
        "ttft_p99_ns": percentile(ttfts, 99.0),
        "ttft_p999_ns": percentile(ttfts, 99.9),
        "span_ns": span,
        "tokens_per_s": gen_total / (span / 1e9),
    }


def grid_workload_totals(q8_cost: dict) -> dict:
    """Integer draw totals of the contended grid workload (8x2, q8)."""
    service_ns = 64 * q8_cost["prefill_ns"] + 40 * q8_cost["decode_ns"]
    mean_gap_ns = service_ns * 10 // (8 * 8 * 2)  # u64 division truncates
    work = generate_workload(4096, "poisson", mean_gap_ns)
    return {
        "prompt_tokens": sum(r[2] for r in work),
        "gen_tokens": sum(r[3] for r in work),
        "head_count": sum(1 for r in work if r[1] == 0),
    }


NOTE = (
    "Analytically seeded baseline for bench_sim --smoke (see "
    "tools/seed_bench_sim.py for the derivations; every pinned value is "
    "a bit-for-bit mirror of the Rust cost model + workload stream, so "
    "the +/-25% gate exists only to absorb pathological last-ulp "
    "divergence between platforms). Null entries are structural gates "
    "for the contended grid cells and the replica-death split: refresh "
    "them from the BENCH_sim.json artifact uploaded by the CI sim-gate "
    "job to activate value gating."
)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", help="write the baseline JSON here")
    args = ap.parse_args()

    gated = {}
    costs = {d: cost_model(d) for d in ("f32", "q8", "q4")}
    for dtype, c in costs.items():
        for k, v in c.items():
            gated[f"cost.{dtype}.{k}"] = v
    for alloc in ("uniform", "pyramid", "adaptive"):
        # budget-conserving plans price decode identically
        gated[f"alloc.q8.decode_ns.{alloc}"] = costs["q8"]["decode_ns"]

    unc = uncontended(costs["f32"])
    for k, v in unc.items():
        gated[f"uncontended.{k}"] = v

    for k, v in grid_workload_totals(costs["q8"]).items():
        gated[f"workload.grid.{k}"] = v

    for routing in ("prefix", "least-loaded", "round-robin"):
        for steal in ("steal", "nosteal"):
            gated[f"grid.{routing}-{steal}.ttft_p99_ns"] = None
            gated[f"grid.{routing}-{steal}.tokens_per_s"] = None
    gated["grid.bursty.ttft_p99_ns"] = None
    gated["grid.bursty.tokens_per_s"] = None

    gated["fail.settled"] = 4096
    gated["fail.completed"] = None
    gated["fail.failed"] = None

    doc = {"bench": "sim", "schema": 1, "note": NOTE, "gated": gated}
    text = json.dumps(doc, indent=2) + "\n"
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text)
        pinned = sum(1 for v in gated.values() if v is not None)
        print(f"wrote {args.out}: {pinned} pinned, "
              f"{len(gated) - pinned} structural")
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
