#!/usr/bin/env python3
"""Compute the deterministic gen_tokens totals of `bench_serve --smoke`.

The smoke's cluster scenarios drive SimEngine replicas whose token
streams are schedule-independent by construction (rust/src/engine/
sim.rs: logits are a pure function of the position), so each request's
generated-token count depends only on its (prompt, seed, max_len) —
never on routing policy, replica assignment, or admission order. This
script ports the relevant pieces bit-for-bit:

* SplitMix64 (rust/src/util/rng.rs) incl. `f64()` and `weighted()`;
* Sampler::sample at temperature 0.7, top_k 0 (engine/sampler.rs);
* sim_logits + the sim tokenizer/decode loop (engine/sim.rs);
* the skewed 24-request workload (benches/bench_serve.rs).

The only non-integer arithmetic is IEEE-754 f64 (plus libm exp), so
the totals printed here match a Rust run on any IEEE platform; the
±25% CI gate absorbs any pathological last-ulp divergence. Use the
output to seed `cluster.*.gen_tokens` in
tools/bench_baselines/BENCH_serve.json, and confirm against the first
uploaded BENCH_serve.json CI artifact.
"""

import math
import struct

M64 = (1 << 64) - 1
SIM_EOS = 0
SIM_BOS = 1
SIM_BYTE_BASE = 16


def f32(x: float) -> float:
    return struct.unpack("<f", struct.pack("<f", x))[0]


class SplitMix64:
    def __init__(self, seed: int) -> None:
        self.state = seed & M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return (z ^ (z >> 31)) & M64

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def weighted(self, weights: list) -> int:
        total = 0.0
        for w in weights:
            total += w
        x = self.f64() * total
        for i, w in enumerate(weights):
            x -= w
            if x <= 0.0:
                return i
        return len(weights) - 1


class Sampler:
    def __init__(self, temperature: float, seed: int) -> None:
        self.temperature = temperature
        self.rng = SplitMix64(seed)

    def sample(self, logits: list) -> int:
        inv_t = 1.0 / self.temperature
        m = -math.inf
        for x in logits:
            m = max(m, x)
        weights = [math.exp((x - m) * inv_t) for x in logits]
        return self.rng.weighted(weights)


def sim_logits(pos: int) -> list:
    r = SplitMix64(0x51E0C0DE ^ ((pos * 0x9E37) & M64))
    return [f32(r.f64()) for _ in range(16)]


def sim_encode(prompt: str) -> list:
    return [SIM_BOS] + [SIM_BYTE_BASE + b for b in prompt.encode()]


def chain_gen_tokens(prompt: str, max_len: int, seed: int) -> int:
    """Mirror of SimEngine prefill-end sampling + decode_step."""
    ids = sim_encode(prompt)
    n = len(ids)
    assert n + 2 <= max_len
    sampler = Sampler(0.7, seed)
    cur = sampler.sample(sim_logits(n - 1))
    pos = n
    gen = []
    while True:
        tok = sampler.sample(sim_logits(pos))
        gen.append(cur)
        pos += 1
        cur = tok
        if tok == SIM_EOS:
            break
        if pos + 1 >= max_len:
            gen.append(tok)
            break
    return len(gen)


def skewed_workload() -> list:
    systems = [
        "system A: you are a careful and methodical math solver, reason step by step, keep it brief, answer",
        "system B: you are a terse coding assistant, answer with a single code line and then stop right there",
        "system C: you translate numbers to words precisely and then immediately stop, no extra text, answer",
    ]
    rng = SplitMix64(0xC1A57E12)
    out = []
    for rid in range(24):
        r = rng.f64()
        sys_ = systems[0] if r < 0.6 else systems[1] if r < 0.9 else systems[2]
        tail = chr(ord("a") + rid)
        out.append((rid, f"{sys_}|{tail}"))
    return out


def main() -> None:
    total = 0
    for rid, prompt in skewed_workload():
        g = chain_gen_tokens(prompt, 224, rid)
        total += g
        print(f"request {rid:>2}  seed {rid:>2}  gen_tokens {g}")
    print(f"\ncluster.<routing>.gen_tokens total: {total}")
    print("(identical for prefix / least-loaded / round-robin: streams are")
    print(" schedule-independent; routing only moves them between replicas)")
    n = len(skewed_workload())
    print("\ntrace.disabled.events: 0")
    print(f"trace.enabled.events: {4 * n}  (submit/admit/first_token/finish x {n}")
    print("  requests; width 1 + prefix cache off => no COW/dequant/evict events)")
    print("trace.enabled.dropped: 0  (4096-event ring never wraps at this scale)")
    print("\nprefix.cold.* (tiered-prefix-cache cell, same skewed workload):")
    print("  hit/cold-token totals are deterministic but depend on radix trim")
    print("  order (LRU leaf demotion under the 4-page hot budget), which this")
    print("  seeder does not port -- seed them null (presence gate) and refresh")
    print("  exact values from the uploaded BENCH_serve.json CI artifact.")
    print("prefix.cold.tiered_beats_hot_only: 1  (bench-asserted invariant:")
    print("  hot+cold at the same hot budget recovers strictly more prefix hit")
    print("  tokens than hot-only; cold hits promote instead of re-prefilling)")


if __name__ == "__main__":
    main()
