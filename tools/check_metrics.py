#!/usr/bin/env python3
"""Metrics docs-drift gate: docs/OBSERVABILITY.md vs rust/src/.

The metrics glossary in ``docs/OBSERVABILITY.md`` is the single source
of truth for every metric the stack records (README/ARCHITECTURE link
there instead of duplicating tables). This tool keeps it honest, in
both directions:

* every metric **registered in code** — a string literal passed to
  ``.counter("…")`` / ``.gauge("…")`` / ``.histogram("…")`` outside
  ``#[cfg(test)]`` blocks — must appear in the glossary, with the same
  kind;
* every metric **named in the glossary** must still exist in code
  (rows whose name contains ``{`` document dynamic families such as
  ``cluster.routed.{i}`` and are skipped — their names are built with
  ``format!`` and cannot be cross-checked statically).

With ``--exposition FILE`` it additionally validates a dumped
Prometheus text exposition (0.0.4) against the format grammar: one
``# TYPE`` line per family with a known kind, every sample naming a
declared family (directly or via ``_sum``/``_count``), every value a
parseable float.

Exit code 0 when everything matches, 1 otherwise (one line per
problem). Self-test: ``python3 tools/test_check_metrics.py``.

Usage: python3 tools/check_metrics.py [repo_root] [--exposition FILE]
"""

import re
import sys
from pathlib import Path

GLOSSARY_DOC = "docs/OBSERVABILITY.md"
GLOSSARY_HEADING = "## Metrics glossary"

# A registration site: `metrics.counter("serve.requests")` etc. The
# leading dot keeps definitions like `fn counter(` out of the match.
REGISTER_RE = re.compile(r"\.(counter|gauge|histogram)\(\s*\"([^\"]+)\"")
# Backticked names in a glossary row's first cell.
NAME_RE = re.compile(r"`([^`]+)`")
PROM_KINDS = {"counter", "gauge", "summary", "histogram"}


def strip_tests(source: str) -> str:
    """Source text truncated at the first ``#[cfg(test)]``.

    Test modules sit at the bottom of every file in this repo, so the
    truncation drops exactly the registrations that exist only to
    exercise the registry — those have no business in the glossary.
    """
    idx = source.find("#[cfg(test)]")
    return source if idx < 0 else source[:idx]


def scan_source(source: str) -> list:
    """``[(kind, name)]`` registered by one file's production code."""
    return [(m.group(1), m.group(2)) for m in REGISTER_RE.finditer(strip_tests(source))]


def code_metrics(rust_src: Path) -> dict:
    """name → kind over every ``.rs`` file under ``rust_src``.

    A name registered under two different kinds is a bug in itself and
    reported as such.
    """
    kinds = {}
    errors = []
    for path in sorted(rust_src.rglob("*.rs")):
        for kind, name in scan_source(path.read_text(encoding="utf-8")):
            prev = kinds.setdefault(name, kind)
            if prev != kind:
                errors.append(f"{name}: registered as both {prev} and {kind}")
    if errors:
        raise ValueError("; ".join(errors))
    return kinds


def glossary_metrics(doc_text: str) -> dict:
    """name → kind from the glossary tables (dynamic ``{…}`` rows skipped)."""
    kinds = {}
    in_section = False
    for line in doc_text.splitlines():
        if line.startswith("## "):
            in_section = line.strip() == GLOSSARY_HEADING
            continue
        if not in_section or not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if len(cells) < 2 or cells[0] in ("metric", "") or set(cells[0]) <= {"-"}:
            continue
        kind = cells[1]
        for name in NAME_RE.findall(cells[0]):
            if "{" in name:
                continue
            kinds[name] = kind
    return kinds


def compare(doc: dict, code: dict) -> list:
    """Drift between the glossary and the registration sites."""
    errors = []
    for name in sorted(set(code) - set(doc)):
        errors.append(f"undocumented: {name} ({code[name]}) is registered but not in the glossary")
    for name in sorted(set(doc) - set(code)):
        errors.append(f"stale doc: {name} is in the glossary but never registered")
    for name in sorted(set(doc) & set(code)):
        if doc[name] != code[name]:
            errors.append(f"kind mismatch: {name} is a {code[name]} in code, {doc[name]} in docs")
    return errors


def check_exposition(text: str) -> list:
    """Grammar errors in a Prometheus text exposition (empty = valid)."""
    errors = []
    families = {}
    for line in text.splitlines():
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE ") :].split()
            if len(parts) != 2:
                errors.append(f"malformed TYPE line: {line!r}")
                continue
            name, kind = parts
            if kind not in PROM_KINDS:
                errors.append(f"unknown family kind in {line!r}")
            if name in families:
                errors.append(f"duplicate TYPE line for {name}")
            families[name] = kind
        elif line and not line.startswith("#"):
            cut = [i for i in (line.find("{"), line.find(" ")) if i >= 0]
            name = line[: min(cut)] if cut else line
            base = name
            for suffix in ("_sum", "_count"):
                if name.endswith(suffix):
                    base = name[: -len(suffix)]
                    break
            if base not in families and name not in families:
                errors.append(f"sample {name} has no TYPE line")
            value = line.rsplit(" ", 1)[-1]
            try:
                float(value)
            except ValueError:
                errors.append(f"sample value does not parse: {line!r}")
    if not families:
        errors.append("empty exposition (no TYPE lines)")
    return errors


def main() -> int:
    argv = sys.argv[1:]
    exposition = None
    if "--exposition" in argv:
        i = argv.index("--exposition")
        try:
            exposition = Path(argv[i + 1])
        except IndexError:
            print("--exposition needs a file argument", file=sys.stderr)
            return 1
        del argv[i : i + 2]
    root = Path(argv[0]).resolve() if argv else Path.cwd()

    doc_path = root / GLOSSARY_DOC
    doc = glossary_metrics(doc_path.read_text(encoding="utf-8"))
    if not doc:
        print(f"check_metrics: no glossary rows found in {GLOSSARY_DOC}", file=sys.stderr)
        return 1
    try:
        code = code_metrics(root / "rust" / "src")
    except ValueError as e:
        print(f"check_metrics: {e}", file=sys.stderr)
        return 1

    errors = compare(doc, code)
    n_expo = 0
    if exposition is not None:
        expo_errors = check_exposition(exposition.read_text(encoding="utf-8"))
        errors.extend(f"{exposition}: {e}" for e in expo_errors)
        n_expo = 1

    for e in errors:
        print(e, file=sys.stderr)
    print(
        f"check_metrics: {len(code)} registered, {len(doc)} documented, "
        f"{n_expo} exposition(s), {len(errors)} problems"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
