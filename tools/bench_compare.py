#!/usr/bin/env python3
"""Perf-regression gate: diff a bench JSON against a checked-in baseline.

Bench binaries (``bench_serve --smoke --out BENCH_serve.json``,
``bench_kvcache --smoke --out BENCH_kvcache.json``) emit::

    {"bench": "serve", "schema": 1, "gated": {...}, "info": {...}}

This tool compares the ``gated`` section against a baseline file from
``tools/bench_baselines/``:

* a **numeric** baseline value gates with a relative tolerance
  (default +/-25%): ``|cur - base| <= tol * max(|base|, 1.0)``;
* a **null** baseline value is a *structural* gate: the metric must
  exist and be numeric in the current run, but its value is not yet
  pinned (used for counters that can only be seeded from a real CI
  run — refresh the baseline from the uploaded ``BENCH_*.json``
  artifact to activate value gating);
* metrics present in the current run but absent from the baseline are
  reported as NEW and pass (add them to the baseline to gate them).

``info`` sections are never gated (wall-clock, machine-dependent).

Exit code 0 when every gated metric passes, 1 otherwise.

Usage: python3 tools/bench_compare.py CURRENT BASELINE [--tolerance 0.25]
"""

import argparse
import json
import sys
from pathlib import Path


def load(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc.get("gated"), dict):
        sys.exit(f"bench_compare: {path} has no 'gated' object")
    return doc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="bench JSON produced by this run")
    ap.add_argument("baseline", help="checked-in baseline JSON")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="relative tolerance for numeric baselines (default 0.25)",
    )
    args = ap.parse_args()

    cur = load(args.current)
    base = load(args.baseline)
    name = base.get("bench", Path(args.baseline).stem)

    failures = 0
    rows = []
    for key, expect in sorted(base["gated"].items()):
        got = cur["gated"].get(key)
        if got is None or not isinstance(got, (int, float)):
            rows.append((key, expect, got, "MISSING"))
            failures += 1
            continue
        if expect is None:
            rows.append((key, expect, got, "present (baseline unseeded)"))
            continue
        delta = abs(got - expect)
        allowed = args.tolerance * max(abs(expect), 1.0)
        if delta <= allowed:
            rows.append((key, expect, got, "ok"))
        else:
            rel = delta / max(abs(expect), 1e-12)
            rows.append((key, expect, got, f"FAIL ({rel:+.1%} vs +/-{args.tolerance:.0%})"))
            failures += 1
    for key in sorted(set(cur["gated"]) - set(base["gated"])):
        rows.append((key, None, cur["gated"][key], "NEW (not gated)"))

    width = max((len(k) for k, *_ in rows), default=10)
    print(f"bench_compare [{name}]: tolerance +/-{args.tolerance:.0%}")
    for key, expect, got, verdict in rows:
        e = "-" if expect is None else f"{expect:.6g}"
        g = "-" if got is None else f"{got:.6g}"
        print(f"  {key:<{width}}  base {e:>12}  cur {g:>12}  {verdict}")
    unseeded = sum(1 for _, e, _, v in rows if e is None and "unseeded" in str(v))
    if unseeded:
        print(
            f"bench_compare: {unseeded} metric(s) structurally gated only — "
            f"refresh {args.baseline} from a CI BENCH artifact to pin values"
        )
    print(f"bench_compare: {len(rows)} metrics, {failures} failing")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
