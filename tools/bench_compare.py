#!/usr/bin/env python3
"""Perf-regression gate: diff a bench JSON against a checked-in baseline.

Bench binaries (``bench_serve --smoke --out BENCH_serve.json``,
``bench_sim --smoke --out BENCH_sim.json``, ...) emit::

    {"bench": "serve", "schema": 1, "gated": {...}, "info": {...}}

This tool compares the ``gated`` section against a baseline file from
``tools/bench_baselines/``:

* a **nonzero numeric** baseline value gates with a relative tolerance
  (default +/-25%): ``|cur - base| <= tol * max(|base|, 1.0)``;
* a **zero** baseline value gates with an *absolute* tolerance
  (``--zero-tolerance``, default 0 — exact). A relative band around
  zero is vacuous, and the ``max(|base|, 1.0)`` floor would silently
  admit anything within +/-tol of a metric pinned at exactly 0 (e.g.
  "no requests failed", "nothing was stolen");
* a **null** baseline value is a *structural* gate: the metric must
  exist and be numeric in the current run, but its value is not yet
  pinned (used for counters that can only be seeded from a real CI
  run — refresh the baseline from the uploaded ``BENCH_*.json``
  artifact to activate value gating);
* metrics present in the current run but absent from the baseline are
  reported as NEW and pass (add them to the baseline to gate them).

``info`` sections are never gated (wall-clock, machine-dependent).

Exit code 0 when every gated metric passes, 1 otherwise.

Usage: python3 tools/bench_compare.py CURRENT BASELINE
           [--tolerance 0.25] [--zero-tolerance 0]

Self-test: python3 tools/test_bench_compare.py
"""

import argparse
import json
import sys
from pathlib import Path


def load(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc.get("gated"), dict):
        sys.exit(f"bench_compare: {path} has no 'gated' object")
    return doc


def compare(cur_gated: dict, base_gated: dict, tolerance: float,
            zero_tolerance: float = 0.0):
    """Compare gated metrics; return (rows, failures).

    Each row is ``(key, expect, got, verdict)``; a verdict starting
    with ``FAIL`` or equal to ``MISSING`` counts as a failure.
    """
    failures = 0
    rows = []
    for key, expect in sorted(base_gated.items()):
        got = cur_gated.get(key)
        if got is None or not isinstance(got, (int, float)) or isinstance(got, bool):
            rows.append((key, expect, got, "MISSING"))
            failures += 1
            continue
        if expect is None:
            rows.append((key, expect, got, "present (baseline unseeded)"))
            continue
        delta = abs(got - expect)
        if expect == 0:
            # zero baselines gate absolutely: a relative band is
            # meaningless and the 1.0 floor would mask regressions
            if delta <= zero_tolerance:
                rows.append((key, expect, got, "ok"))
            else:
                rows.append((
                    key, expect, got,
                    f"FAIL (|delta| {delta:.6g} vs +/-{zero_tolerance:.6g} abs)",
                ))
                failures += 1
            continue
        allowed = tolerance * max(abs(expect), 1.0)
        if delta <= allowed:
            rows.append((key, expect, got, "ok"))
        else:
            rel = delta / abs(expect)
            rows.append((key, expect, got,
                         f"FAIL ({rel:+.1%} vs +/-{tolerance:.0%})"))
            failures += 1
    for key in sorted(set(cur_gated) - set(base_gated)):
        rows.append((key, None, cur_gated[key], "NEW (not gated)"))
    return rows, failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="bench JSON produced by this run")
    ap.add_argument("baseline", help="checked-in baseline JSON")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="relative tolerance for nonzero numeric baselines (default 0.25)",
    )
    ap.add_argument(
        "--zero-tolerance",
        type=float,
        default=0.0,
        help="absolute tolerance for baselines pinned at exactly 0 "
             "(default 0: the current value must also be 0)",
    )
    args = ap.parse_args()

    cur = load(args.current)
    base = load(args.baseline)
    name = base.get("bench", Path(args.baseline).stem)

    rows, failures = compare(
        cur["gated"], base["gated"], args.tolerance, args.zero_tolerance
    )

    width = max((len(k) for k, *_ in rows), default=10)
    print(
        f"bench_compare [{name}]: tolerance +/-{args.tolerance:.0%} "
        f"(zero baselines: +/-{args.zero_tolerance:.6g} abs)"
    )
    for key, expect, got, verdict in rows:
        e = "-" if expect is None else f"{expect:.6g}"
        g = "-" if got is None else f"{got:.6g}"
        print(f"  {key:<{width}}  base {e:>12}  cur {g:>12}  {verdict}")
    unseeded = sum(1 for _, e, _, v in rows if e is None and "unseeded" in str(v))
    if unseeded:
        print(
            f"bench_compare: {unseeded} metric(s) structurally gated only — "
            f"refresh {args.baseline} from a CI BENCH artifact to pin values"
        )
    print(f"bench_compare: {len(rows)} metrics, {failures} failing")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
