#!/usr/bin/env python3
"""Analytically seed tools/bench_baselines/BENCH_slo.json for bench_serve's SLO leg.

``bench_serve --smoke --slo-out BENCH_slo.json`` emits a perf *model*
document: every gated number is a pure function of the workload seed
(0x510AD), the mixed-workload generator (rust/src/engine/workload.rs),
and the admission controller (rust/src/engine/slo.rs). This script
re-derives the pinnable subset bit-for-bit in Python:

* ``workload.<arrival>.*`` — integer draw totals (Σ prompt tokens,
  Σ gen tokens, per-class counts) of the 4096-request mixed stream for
  every arrival process. Exponential gap *values* go through libm
  ``ln`` (not bit-pinned across platforms) but each gap consumes
  exactly one ``f64()`` draw at a fixed stream position, so the
  class/prompt/gen draws — and therefore the totals — are exact for
  uniform, poisson, bursty, *and* diurnal arrivals;
* ``admission.uniform.<dtype>.*`` — the accept/queue/reject split of
  the uniform-arrival stream (integer arrival times, width-flattened)
  offered to ``AdmissionController`` at ``byte_capacity(1, 1)``. All
  controller arithmetic is u64, so the decision stream is a closed
  form. f32 vs q4 pins the hyper-scaling dividend: same byte capacity,
  ~7x smaller per-request demand, strictly more admitted load;
* ``slo.q4_admits_more_than_f32`` / ``slo.edf_beats_fcfs`` — the
  issue's acceptance invariants. The bench asserts both at runtime and
  emits 1; the baselines pin them at 1 so a silent flip (emitting 0)
  fails the gate even if the assert were removed;
* ``sweep.r{64,128,256,512}.*`` — virtual-time TTFT tails + goodput of
  the hyperscale sweep. Deterministic, but they run the full
  discrete-event simulator, which this script does not mirror: emitted
  as null (structural gate). Refresh from the BENCH_slo.json artifact
  uploaded by CI to activate value gating.

The script also prints the golden draw-total tuples pinned by
``per_process_draw_totals_are_pinned`` in rust/src/engine/workload.rs —
the third mirror of the same stream.

Usage: python3 tools/seed_bench_slo.py [--out tools/bench_baselines/BENCH_slo.json]

Without --out the baseline JSON is printed to stdout.
"""

import argparse
import json
import math
import sys

M64 = (1 << 64) - 1

# -- rust/src/util/rng.rs ---------------------------------------------------


class SplitMix64:
    def __init__(self, seed: int) -> None:
        self.state = seed & M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return (z ^ (z >> 31)) & M64

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def weighted(self, weights: list) -> int:
        total = 0.0
        for w in weights:
            total += w
        x = self.f64() * total
        for i, w in enumerate(weights):
            x -= w
            if x <= 0.0:
                return i
        return len(weights) - 1


def round_half_up(x: float) -> int:
    """Rust ``f64::round`` (half away from zero) for non-negative x."""
    assert x >= 0.0
    return math.floor(x + 0.5)


# -- rust/src/engine/timeflow.rs CostModel (Llama 3.1 8B on H100) -----------
#
# Same derivation as tools/seed_bench_sim.py, plus kv_bytes_per_token
# (the admission controller's demand unit).

N_LAYERS = 32.0
D_MODEL = 4096.0
D_FF = 14336.0
D_KV = 1024.0
VOCAB = 128256.0
W_BYTES = 2.0  # weight/activation bytes per element (bf16)

FLOPS_PER_S = 989.5e12  # H100 SXM bf16 dense
BYTES_PER_S = 3.35e12  # H100 HBM

HEAD_DIM = 64
REF_BATCH = 64.0
REF_SEQ = 4096.0
REF_CR = 4.0
UPLOAD_BYTES_PER_S = 64e9
DEQUANT_BYTES_PER_S = 8e9


def row_payload_bytes(dtype: str, row_len: int) -> int:
    """KvDtype::row_payload_bytes (kvcache/quant.rs)."""
    if dtype == "f32":
        return row_len * 4
    codes = row_len if dtype == "q8" else (row_len + 1) // 2
    return codes + 5  # codes + f32 scale + u8 zero-point


def flops(batch: float, seq: float) -> float:
    per_layer = (
        6.0 * D_MODEL * D_FF
        + 4.0 * D_MODEL * D_MODEL
        + 4.0 * D_MODEL * D_KV
        + 4.0 * D_MODEL * seq
    )
    return N_LAYERS * batch * per_layer + 2.0 * batch * D_MODEL * VOCAB


def kv_reads(kv_bytes: float, batch: float, seq: float) -> float:
    return N_LAYERS * 2.0 * batch * seq * D_KV * kv_bytes


def reads(kv_bytes: float, batch: float, seq: float) -> float:
    params_per_layer = (
        3.0 * D_MODEL * D_FF + 2.0 * D_MODEL * D_MODEL + 2.0 * D_MODEL * D_KV
    )
    return (N_LAYERS * params_per_layer + D_MODEL * VOCAB) * W_BYTES + kv_reads(
        kv_bytes, batch, seq
    )


def cost_model(dtype: str) -> dict:
    """CostModel::default_for(dtype, Uniform): prefill/decode ns + KV bytes."""
    kv_bytes = row_payload_bytes(dtype, HEAD_DIM) / float(HEAD_DIM)
    prefill_s = flops(1.0, REF_SEQ) / FLOPS_PER_S

    layers = int(N_LAYERS)
    kv_heads = max(int(D_KV) // HEAD_DIM, 1)
    cells = float(layers * kv_heads)
    glob = int((REF_SEQ / REF_CR) * cells)
    eff_seq = min(glob / cells, REF_SEQ)
    t_compute = flops(REF_BATCH, REF_SEQ) / FLOPS_PER_S
    t_memory = (
        reads(kv_bytes, REF_BATCH, 0.0) + kv_reads(kv_bytes, REF_BATCH, eff_seq)
    ) / BYTES_PER_S
    decode_s = max(t_compute, t_memory) / REF_BATCH

    rows_per_token = N_LAYERS * (D_KV / float(HEAD_DIM)) * 2.0
    bytes_per_token = rows_per_token * float(row_payload_bytes(dtype, HEAD_DIM))

    return {
        "prefill_ns": max(round_half_up(prefill_s * 1e9), 1),
        "decode_ns": max(round_half_up(decode_s * 1e9), 1),
        # exact: rows_per_token and the payload bytes are integers
        "kv_bytes_per_token": int(bytes_per_token),
    }


# -- rust/src/engine/workload.rs --------------------------------------------

SEED = 0x510AD  # workload.rs test SEED == bench_serve SLO_SEED
REQUESTS = 4096
N_PROMPTS = 64
MEAN_GAP_NS = 1_250_000
BURST = 32
MIX = [0.70, 0.20, 0.10]
VOTE_WIDTH = 4
DIURNAL_GAP_MULT = [1, 1, 2, 4, 8, 4, 2, 1]

ARRIVALS = ("uniform", "poisson", "bursty", "diurnal")
CLASSES = ("chat", "long_context", "voting")
PROMPT_RANGE = {"chat": (32, 96), "long_context": (256, 768), "voting": (32, 96)}
GEN_RANGE = {"chat": (16, 64), "long_context": (32, 96), "voting": (16, 64)}


def zipf_weights(n: int, s: float) -> list:
    return [1.0 / k if s == 1.0 else float(k) ** (-s) for k in range(1, n + 1)]


def exp_gap(rng: SplitMix64, mean_ns: int) -> int:
    u = rng.f64()
    # libm ln is not bit-pinned across platforms, but the gap consumes
    # exactly one draw either way; the gated totals depend only on
    # stream position, never on gap values
    return round_half_up(-math.log(1.0 - u) * float(mean_ns))


def generate_mixed_workload(requests: int, arrival: str) -> list:
    """Mirror of generate_mixed_workload: (arrival_ns, class, width, prompt, gen).

    Draw order per request is fixed — gap, class, prompt id, gen
    tokens. Uniform arrivals consume no gap draw; bursty consumes one
    per burst head only.
    """
    rng = SplitMix64(SEED)
    zipf = zipf_weights(N_PROMPTS, 1.0)
    phase_len = max(requests // len(DIURNAL_GAP_MULT), 1)
    t = 0
    out = []
    for i in range(requests):
        if arrival == "uniform":
            t += MEAN_GAP_NS
        elif arrival == "poisson":
            t += exp_gap(rng, MEAN_GAP_NS)
        elif arrival == "bursty":
            if i % BURST == 0:
                t += exp_gap(rng, MEAN_GAP_NS * BURST)
        elif arrival == "diurnal":
            mult = DIURNAL_GAP_MULT[(i // phase_len) % len(DIURNAL_GAP_MULT)]
            t += exp_gap(rng, MEAN_GAP_NS * mult)
        else:
            raise ValueError(arrival)
        cname = CLASSES[rng.weighted(MIX)]
        raw_id = rng.weighted(zipf)
        p_lo, p_hi = PROMPT_RANGE[cname]
        prompt = p_lo + (raw_id * 37) % (p_hi - p_lo + 1)
        g_lo, g_hi = GEN_RANGE[cname]
        gen = g_lo + rng.below(g_hi - g_lo + 1)
        width = VOTE_WIDTH if cname == "voting" else 1
        out.append((t, cname, width, prompt, gen))
    return out


def draw_totals(work: list) -> tuple:
    """(Σ prompt, Σ gen, chat, long_context, voting) — the golden tuple
    pinned by per_process_draw_totals_are_pinned in workload.rs."""
    counts = {c: 0 for c in CLASSES}
    for _, cname, _, _, _ in work:
        counts[cname] += 1
    return (
        sum(r[3] for r in work),
        sum(r[4] for r in work),
        counts["chat"],
        counts["long_context"],
        counts["voting"],
    )


# -- rust/src/engine/slo.rs AdmissionController -----------------------------

LANE_RESIDENT_TOKENS = 1024
SERVICE_WINDOW_SLACK = 4


def byte_capacity(replicas: int, lanes: int) -> int:
    f32_bytes = cost_model("f32")["kv_bytes_per_token"]
    return replicas * lanes * LANE_RESIDENT_TOKENS * f32_bytes


def admission_counts(work: list, cost: dict, capacity: int) -> tuple:
    """(accepted, queued, rejected) of the width-flattened stream.

    Bit-for-bit port of AdmissionController::offer: u64 ledger with
    per-request commitment windows, accepted set capped at capacity,
    queued headroom capped at 2x capacity with doubled windows.
    """
    kv = cost["kv_bytes_per_token"]
    prefill = cost["prefill_ns"]
    decode = cost["decode_ns"]
    ledger = []  # (expiry_ns, bytes, accepted)
    accepted_bytes = queued_bytes = 0
    accepted = queued = rejected = 0
    for t, _cname, width, prompt, gen in work:
        for _ in range(width):
            keep = []
            for expiry, b, acc in ledger:
                if expiry <= t:
                    if acc:
                        accepted_bytes -= b
                    else:
                        queued_bytes -= b
                else:
                    keep.append((expiry, b, acc))
            ledger = keep
            d = (prompt + gen) * kv
            w = (prompt * prefill + gen * decode) * SERVICE_WINDOW_SLACK
            if accepted_bytes + d <= capacity:
                ledger.append((t + w, d, True))
                accepted_bytes += d
                accepted += 1
            elif accepted_bytes + queued_bytes + d <= 2 * capacity:
                ledger.append((t + 2 * w, d, False))
                queued_bytes += d
                queued += 1
            else:
                rejected += 1
    return accepted, queued, rejected


# -- baseline document ------------------------------------------------------

NOTE = (
    "Analytically seeded baseline for the bench_serve SLO leg (see "
    "tools/seed_bench_slo.py for the derivations). workload.* draw "
    "totals and admission.uniform.* splits are bit-for-bit mirrors of "
    "the seeded stream + u64 admission ledger, so the +/-25% gate "
    "exists only to absorb pathological last-ulp divergence between "
    "platforms. slo.* indicators pin the issue's acceptance invariants "
    "at 1 (the bench asserts them at runtime). Null entries are "
    "structural gates for the 64-512-replica sweep, which runs the "
    "full discrete-event simulator: refresh them from the "
    "BENCH_slo.json artifact uploaded by CI to activate value gating."
)

SWEEP_REPLICAS = (64, 128, 256, 512)
SWEEP_METRICS = ("ttft_p50_ns", "ttft_p99_ns", "ttft_p999_ns", "goodput_tokens_per_s")


def build_gated() -> dict:
    gated = {}
    for arrival in ARRIVALS:
        work = generate_mixed_workload(REQUESTS, arrival)
        prompt, gen, chat, long_ctx, voting = draw_totals(work)
        gated[f"workload.{arrival}.prompt_tokens"] = prompt
        gated[f"workload.{arrival}.gen_tokens"] = gen
        gated[f"workload.{arrival}.chat"] = chat
        gated[f"workload.{arrival}.long_context"] = long_ctx
        gated[f"workload.{arrival}.voting"] = voting

    uniform = generate_mixed_workload(REQUESTS, "uniform")
    capacity = byte_capacity(1, 1)
    admitted = {}
    for dtype in ("f32", "q4"):
        acc, q, rej = admission_counts(uniform, cost_model(dtype), capacity)
        gated[f"admission.uniform.{dtype}.accepted"] = acc
        gated[f"admission.uniform.{dtype}.queued"] = q
        gated[f"admission.uniform.{dtype}.rejected"] = rej
        admitted[dtype] = acc
    assert admitted["q4"] > admitted["f32"], (
        "q4 must admit strictly more than f32 at the same byte capacity"
    )
    gated["slo.q4_admits_more_than_f32"] = 1
    gated["slo.edf_beats_fcfs"] = 1

    for replicas in SWEEP_REPLICAS:
        for metric in SWEEP_METRICS:
            gated[f"sweep.r{replicas}.{metric}"] = None
    return gated


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", help="write the baseline JSON here")
    args = ap.parse_args()

    gated = build_gated()
    doc = {"bench": "slo", "schema": 1, "note": NOTE, "gated": gated}
    text = json.dumps(doc, indent=2) + "\n"
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text)
        pinned = sum(1 for v in gated.values() if v is not None)
        print(f"wrote {args.out}: {pinned} pinned, "
              f"{len(gated) - pinned} structural")
        for arrival in ARRIVALS:
            totals = draw_totals(generate_mixed_workload(REQUESTS, arrival))
            print(f"GOLDEN_{arrival.upper()}: {totals}")
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
