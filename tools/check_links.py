#!/usr/bin/env python3
"""Offline markdown link checker for README.md + docs/*.md.

Validates every ``[text](target)`` link whose target is a relative
path: the file must exist, and if the target carries a ``#anchor`` the
destination file must contain a heading that slugifies to it
(GitHub-style). External (http/https/mailto) links are skipped — CI
runs offline-safe.

Exit code 0 when every link resolves, 1 otherwise (one line per
broken link).

Usage: python3 tools/check_links.py [repo_root]
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
FENCE_RE = re.compile(r"^\s*(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub-flavoured heading → anchor slug."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def prose_lines(path: Path):
    """Lines of the file with fenced code blocks removed.

    A ``#`` shell comment (or Rust attribute, YAML comment, …) inside a
    ``` fence is not a heading: keeping those lines used to mint
    phantom anchors, so links to headings that don't exist passed the
    check. Links inside fences are example payloads, not navigation —
    they are skipped for the same reason.
    """
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield line


def anchors_of(path: Path) -> set:
    slugs = set()
    seen = {}
    for line in prose_lines(path):
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = slugify(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_file(md: Path, root: Path) -> list:
    errors = []
    for m in LINK_RE.finditer("\n".join(prose_lines(md))):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(root)}: broken link '{target}'")
                continue
        else:
            dest = md
        if anchor and dest.suffix == ".md":
            if anchor not in anchors_of(dest):
                errors.append(
                    f"{md.relative_to(root)}: missing anchor '#{anchor}' "
                    f"in {dest.relative_to(root)}"
                )
    return errors


def main() -> int:
    root = Path(sys.argv[1]).resolve() if len(sys.argv) > 1 else Path.cwd()
    files = [root / "README.md"] + sorted((root / "docs").glob("*.md"))
    files = [f for f in files if f.exists()]
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    errors = []
    n_links = 0
    for md in files:
        n_links += len(LINK_RE.findall("\n".join(prose_lines(md))))
        errors.extend(check_file(md, root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {n_links} links, {len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
