#!/usr/bin/env python3
"""Seed tools/bench_baselines/BENCH_policies.json deterministically.

Mirrors the `bench_policies --smoke` grid exactly (see
rust/benches/bench_policies.rs): geometry L4xH2, 320 slots, 120 steps,
synthetic attention `((i % 97) as f32) * 0.03125` (exact dyadic values,
so every f64 accumulation here is bit-identical to the Rust run),
`attn_self = 0.25`, `alpha = 0.6`, per-head budget 40 (global 320).

The occupancy counters are budget-determined, not score-determined:

* vanilla / quest never evict          -> 120 live per (l, h) cell;
* dms / dms_immediate window 16        -> 16 per cell;
* dmc merges every step (alpha > 0.5)  -> 1 per cell;
* window / tova / h2o enforce the plan -> exactly plan.budget(l, h),
  summing to the conserved global 320 under every allocator.

Only the adaptive plan's per-cell budgets need real arithmetic (the
attention-perplexity apportionment below, mirroring
rust/src/compress/budget.rs). Run and redirect:

    python3 tools/seed_bench_policies.py > tools/bench_baselines/BENCH_policies.json
"""

import json
import math
import struct

LAYERS, HEADS, SLOTS = 4, 2, 320
LH = LAYERS * HEADS
STEPS = 120
PER_HEAD = 40
GLOBAL = PER_HEAD * LH
MIN_SHARE = 0.25


def f32(x: float) -> float:
    return struct.unpack("<f", struct.pack("<f", x))[0]


def attn_value(i: int) -> float:
    # ((i % 97) as f32) * 0.03125 — exact in f32 and f64
    return f32(f32(i % 97) * f32(0.03125))


def perplexities() -> list:
    """AttnStats::observe_attn + perplexities, one observation."""
    perps = []
    for lh in range(LH):
        row = [attn_value(lh * SLOTS + s) for s in range(SLOTS)]
        total = 0.25  # attn_self first, as in the Rust loop
        for a in row:
            total += a
        h = 0.0
        for a in row:
            p = a / total
            if p > 0.0:
                h -= p * math.log(p)
        p = 0.25 / total
        if p > 0.0:
            h -= p * math.log(p)
        perps.append(math.exp(h))  # steps == 1
    return perps


def apportion(global_budget: int, weights: list, min_per_cell: int) -> list:
    """Largest-remainder apportionment (mirror of budget.rs)."""
    n = len(weights)
    floor = min(min_per_cell, global_budget // n)
    rem = global_budget - floor * n
    w = [x if (math.isfinite(x) and x > 0.0) else 0.0 for x in weights]
    if sum(w) <= 0.0:
        w = [1.0] * n
    total_w = 0.0
    for x in w:
        total_w += x
    quotas = [rem * x / total_w for x in w]
    base = [int(q) for q in quotas]
    assigned = sum(base)
    assert assigned <= rem, "trunc overshoot (mirror the Rust guard if this fires)"
    order = sorted(range(n), key=lambda i: (-(quotas[i] - base[i]), i))
    for i in order[: rem - assigned]:
        base[i] += 1
    return [b + floor for b in base]


def floor_per_cell(global_budget: int, cells: int) -> int:
    equal = global_budget / cells
    return min(max(int(MIN_SHARE * equal), 1), global_budget // cells)


def plans() -> dict:
    uniform = [PER_HEAD] * LH
    pyr_weights = [float(LAYERS - l) for l in range(LAYERS) for _ in range(HEADS)]
    pyramid = apportion(GLOBAL, pyr_weights, floor_per_cell(GLOBAL, LH))
    adaptive = apportion(GLOBAL, perplexities(), floor_per_cell(GLOBAL, LH))
    return {"uniform": uniform, "pyramid": pyramid, "adaptive": adaptive}


def main() -> None:
    all_plans = plans()
    gated = {}
    for alloc, plan in all_plans.items():
        assert sum(plan) == GLOBAL, (alloc, plan)
        assert all(b < STEPS for b in plan), (
            f"{alloc}: a budget >= {STEPS} steps would cap below plan "
            f"(update the seeded expectations): {plan}"
        )
        gated[f"plan.{alloc}.tokens"] = float(GLOBAL)
        per_policy = {
            "vanilla": [STEPS] * LH,
            "quest": [STEPS] * LH,
            "dms": [16] * LH,
            "dms_immediate": [16] * LH,
            "dmc": [1] * LH,
            "window": plan,
            "tova": plan,
            "h2o": plan,
        }
        for policy, cells in per_policy.items():
            live = sum(cells)
            key = f"policy.{policy}.{alloc}"
            gated[f"{key}.live_tokens"] = float(live)
            gated[f"{key}.live_min_lh"] = float(min(cells))
            gated[f"{key}.live_max_lh"] = float(max(cells))
            gated[f"{key}.live_fraction"] = live / (LH * SLOTS)
            # eviction throughput is machine-dependent: null = the
            # structural gate in bench_compare.py (must exist and be
            # numeric, value never compared)
            gated[f"{key}.tokens_per_s"] = None
    doc = {
        "bench": "policies",
        "schema": 1,
        "note": (
            "Seed baseline for the policy x allocator occupancy smoke "
            "(bench_policies --smoke). All values are deterministic "
            "occupancy counters computed by tools/seed_bench_policies.py, "
            "which mirrors the synthetic smoke loop exactly; wall-clock "
            "tokens/s (eviction throughput) is machine-dependent, so its "
            "entries are null = structural gate only (must exist and be "
            "numeric; never value-compared). "
            f"Adaptive plan cells: {all_plans['adaptive']}."
        ),
        "gated": gated,
    }
    print(json.dumps(doc, indent=2))


if __name__ == "__main__":
    main()
