#!/usr/bin/env python3
"""Self-test for tools/check_metrics.py (run by CI before the gate).

Covers the three moving parts on synthetic inputs: the source scanner
(registration regex + ``#[cfg(test)]`` truncation), the glossary table
parser (multi-name cells, dynamic-family skip), the drift comparison
(both directions + kind mismatch), and the Prometheus exposition
grammar checker — plus an end-to-end run against the real repo, so a
drifted glossary fails this test on the spot.
"""

import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
import check_metrics as cm

FAILURES = []


def check(name: str, cond: bool, detail: str = "") -> None:
    status = "ok" if cond else "FAIL"
    print(f"  {status}  {name}{'  ' + detail if detail and not cond else ''}")
    if not cond:
        FAILURES.append(name)


RUST_SNIPPET = '''
impl Engine {
    fn tick(&mut self) {
        self.metrics.counter("serve.requests").add(1);
        self.metrics
            .gauge("engine.active_lanes")
            .set(lanes as f64);
        self.metrics.histogram("serve.ttft_ms").record(ms);
        let fam = format!("cluster.routed.{i}"); // dynamic: not matched
        self.metrics.counter(&fam).add(1);
    }
}

#[cfg(test)]
mod tests {
    fn t(m: &Registry) {
        m.counter("test.only.metric").add(1); // must not leak out
    }
}
'''

DOC_SNIPPET = """
# Observability

## Exporters

Not a glossary row: | `serve.fake` | counter | decoy outside section |

## Metrics glossary

| metric | kind | meaning |
|--------|------|---------|
| `serve.requests` | counter | completed requests |
| `serve.ttft_ms` / `serve.e2e_ms` | histogram | latency pair |
| `engine.active_lanes` | gauge | lanes running a chain |
| `cluster.routed.{i}` | counter | dynamic family, skipped |

## After

| `serve.after` | counter | decoy after the section |
"""

GOOD_EXPO = """# TYPE serve_requests counter
serve_requests 42
# TYPE serve_ttft_ms summary
serve_ttft_ms{quantile="0.5"} 1.5
serve_ttft_ms_sum 120.5
serve_ttft_ms_count 80
# TYPE engine_active_lanes gauge
engine_active_lanes{replica="0"} 2
"""


def main() -> int:
    print("check_metrics self-test")

    print("source scanner:")
    found = cm.scan_source(RUST_SNIPPET)
    check("finds same-line registrations", ("counter", "serve.requests") in found)
    check("finds histograms", ("histogram", "serve.ttft_ms") in found)
    check(
        "multiline chains: builder on the next line still matches",
        ("gauge", "engine.active_lanes") in found,
        str(found),
    )
    check("skips #[cfg(test)] registrations", all(n != "test.only.metric" for _, n in found))
    check("skips format!-built dynamic names", all("{" not in n for _, n in found))

    print("glossary parser:")
    doc = cm.glossary_metrics(DOC_SNIPPET)
    check("parses single-name rows", doc.get("serve.requests") == "counter")
    check(
        "splits multi-name cells",
        doc.get("serve.ttft_ms") == "histogram" and doc.get("serve.e2e_ms") == "histogram",
    )
    check("skips dynamic {…} rows", "cluster.routed.{i}" not in doc)
    check("ignores rows outside the section", "serve.fake" not in doc and "serve.after" not in doc)

    print("drift comparison:")
    check("clean when aligned", not cm.compare({"a": "counter"}, {"a": "counter"}))
    errs = cm.compare({"a": "counter"}, {"a": "counter", "b": "gauge"})
    check("flags undocumented metrics", any("undocumented: b" in e for e in errs), str(errs))
    errs = cm.compare({"a": "counter", "gone": "gauge"}, {"a": "counter"})
    check("flags stale doc rows", any("stale doc: gone" in e for e in errs), str(errs))
    errs = cm.compare({"a": "counter"}, {"a": "gauge"})
    check("flags kind mismatches", any("kind mismatch: a" in e for e in errs), str(errs))

    print("exposition grammar:")
    check("accepts a valid exposition", not cm.check_exposition(GOOD_EXPO))
    check(
        "rejects duplicate TYPE lines",
        any(
            "duplicate" in e
            for e in cm.check_exposition("# TYPE a counter\n# TYPE a counter\na 1\n")
        ),
    )
    check(
        "rejects undeclared samples",
        any("no TYPE line" in e for e in cm.check_exposition("# TYPE a counter\nb 1\n")),
    )
    check(
        "rejects unknown kinds",
        any("unknown" in e for e in cm.check_exposition("# TYPE a sketch\na 1\n")),
    )
    check(
        "rejects unparsable values",
        any("does not parse" in e for e in cm.check_exposition("# TYPE a counter\na x\n")),
    )
    check("rejects empty input", any("empty" in e for e in cm.check_exposition("")))

    print("end-to-end (real repo):")
    root = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).with_name("check_metrics.py")), str(root)],
        capture_output=True,
        text=True,
    )
    check(
        "repo glossary matches the code",
        proc.returncode == 0,
        (proc.stdout + proc.stderr).strip(),
    )

    if FAILURES:
        print(f"FAILED: {len(FAILURES)} check(s): {', '.join(FAILURES)}")
        return 1
    print("all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
