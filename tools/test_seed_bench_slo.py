#!/usr/bin/env python3
"""Self-test for the BENCH_slo seeder (stdlib only).

Run directly (``python3 tools/test_seed_bench_slo.py``) or via
``python3 -m unittest`` from ``tools/``. The golden tuples here are the
same values pinned by ``per_process_draw_totals_are_pinned`` in
rust/src/engine/workload.rs — three mirrors (Rust generator, Python
seeder, this test) of one seeded stream, so a drift in any one of them
fails a gate before it can reseed a wrong baseline.
"""

import sys
import unittest
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
from seed_bench_slo import (  # noqa: E402
    REQUESTS,
    admission_counts,
    build_gated,
    byte_capacity,
    cost_model,
    draw_totals,
    generate_mixed_workload,
)

# (Σ prompt_tokens, Σ gen_tokens, chat, long_context, voting) at
# 4096 requests, seed 0x510AD — keep in sync with workload.rs.
GOLDEN = {
    "uniform": (523956, 185181, 2846, 820, 430),
    "poisson": (522938, 183742, 2866, 818, 412),
    "bursty": (538826, 184713, 2833, 862, 401),
    "diurnal": (522938, 183742, 2866, 818, 412),
}


class DrawTotals(unittest.TestCase):
    def test_per_process_draw_totals_match_rust_goldens(self):
        for arrival, want in GOLDEN.items():
            got = draw_totals(generate_mixed_workload(REQUESTS, arrival))
            self.assertEqual(got, want, f"arrival {arrival}")

    def test_class_counts_cover_every_request(self):
        for arrival, (_, _, chat, long_ctx, voting) in GOLDEN.items():
            self.assertEqual(chat + long_ctx + voting, REQUESTS, arrival)

    def test_poisson_and_diurnal_draw_streams_coincide(self):
        # both consume exactly one gap draw per request, so only the
        # arrival times differ (the Rust suite pins the same alignment)
        self.assertEqual(GOLDEN["poisson"], GOLDEN["diurnal"])
        p = generate_mixed_workload(256, "poisson")
        d = generate_mixed_workload(256, "diurnal")
        self.assertEqual(
            [(c, w, pt, g) for _, c, w, pt, g in p],
            [(c, w, pt, g) for _, c, w, pt, g in d],
        )

    def test_same_seed_is_bit_identical(self):
        for arrival in GOLDEN:
            self.assertEqual(
                generate_mixed_workload(512, arrival),
                generate_mixed_workload(512, arrival),
            )


class CostModel(unittest.TestCase):
    def test_matches_rust_pinned_pricing(self):
        # CostModel::default_for(dtype, Uniform) — values pinned by the
        # BENCH_sim baseline and the timeflow unit tests
        self.assertEqual(
            cost_model("f32"),
            {"prefill_ns": 17339, "decode_ns": 150136, "kv_bytes_per_token": 262144},
        )
        self.assertEqual(
            cost_model("q4"),
            {"prefill_ns": 17339, "decode_ns": 81587, "kv_bytes_per_token": 37888},
        )


class Admission(unittest.TestCase):
    def test_conservation_and_q4_dividend(self):
        work = generate_mixed_workload(REQUESTS, "uniform")
        offers = sum(w for _, _, w, _, _ in work)
        capacity = byte_capacity(1, 1)
        split = {}
        for dtype in ("f32", "q4"):
            acc, q, rej = admission_counts(work, cost_model(dtype), capacity)
            self.assertEqual(acc + q + rej, offers, dtype)
            split[dtype] = acc
        self.assertGreater(split["q4"], split["f32"])

    def test_tiny_capacity_still_conserves(self):
        work = generate_mixed_workload(64, "uniform")
        offers = sum(w for _, _, w, _, _ in work)
        c = cost_model("f32")
        acc, q, rej = admission_counts(work, c, c["kv_bytes_per_token"] * 48)
        self.assertEqual(acc + q + rej, offers)
        self.assertGreater(rej, 0, "48-token capacity must reject most load")


class Baseline(unittest.TestCase):
    def test_gated_keys_are_complete(self):
        gated = build_gated()
        for arrival in GOLDEN:
            for m in ("prompt_tokens", "gen_tokens", "chat", "long_context", "voting"):
                self.assertIn(f"workload.{arrival}.{m}", gated)
        for dtype in ("f32", "q4"):
            for m in ("accepted", "queued", "rejected"):
                self.assertIn(f"admission.uniform.{dtype}.{m}", gated)
        self.assertEqual(gated["slo.q4_admits_more_than_f32"], 1)
        self.assertEqual(gated["slo.edf_beats_fcfs"], 1)
        sweep = [k for k, v in gated.items() if k.startswith("sweep.") and v is None]
        self.assertEqual(len(sweep), 16, "4 replica counts x 4 metrics, structural")


if __name__ == "__main__":
    unittest.main()
