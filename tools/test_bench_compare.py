#!/usr/bin/env python3
"""Self-test for the bench_compare perf-regression gate (stdlib only).

Run directly (``python3 tools/test_bench_compare.py``) or via
``python3 -m unittest`` from ``tools/``. CI runs it in the lint/tools
leg so a gate bug fails the build before it can wave a regression
through.
"""

import json
import subprocess
import sys
import tempfile
import unittest
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
from bench_compare import compare  # noqa: E402


def verdicts(rows):
    return {k: v for k, _, _, v in rows}


class CompareNonZero(unittest.TestCase):
    def test_within_relative_tolerance_passes(self):
        rows, failures = compare({"a": 110.0}, {"a": 100.0}, 0.25)
        self.assertEqual(failures, 0)
        self.assertEqual(verdicts(rows)["a"], "ok")

    def test_outside_relative_tolerance_fails(self):
        rows, failures = compare({"a": 140.0}, {"a": 100.0}, 0.25)
        self.assertEqual(failures, 1)
        self.assertIn("FAIL", verdicts(rows)["a"])

    def test_small_nonzero_baseline_keeps_absolute_floor(self):
        # |base| < 1 gates against tol * 1.0, not tol * |base|
        rows, failures = compare({"a": 0.3}, {"a": 0.1}, 0.25)
        self.assertEqual(failures, 0, "0.2 absolute delta within 0.25 floor")
        _, failures = compare({"a": 0.4}, {"a": 0.1}, 0.25)
        self.assertEqual(failures, 1, "0.3 absolute delta beyond 0.25 floor")

    def test_negative_baseline_uses_magnitude(self):
        _, failures = compare({"a": -110.0}, {"a": -100.0}, 0.25)
        self.assertEqual(failures, 0)
        _, failures = compare({"a": -140.0}, {"a": -100.0}, 0.25)
        self.assertEqual(failures, 1)


class CompareZeroBaseline(unittest.TestCase):
    """The regression this suite exists for: a baseline pinned at 0
    must not silently admit anything within +/-tolerance."""

    def test_zero_baseline_requires_zero_by_default(self):
        rows, failures = compare({"failed": 0}, {"failed": 0}, 0.25)
        self.assertEqual(failures, 0)
        self.assertEqual(verdicts(rows)["failed"], "ok")

    def test_zero_baseline_rejects_small_drift(self):
        # pre-fix behaviour: 0.2 <= 0.25 * max(0, 1.0) would pass
        rows, failures = compare({"failed": 0.2}, {"failed": 0}, 0.25)
        self.assertEqual(failures, 1)
        self.assertIn("FAIL", verdicts(rows)["failed"])
        self.assertIn("abs", verdicts(rows)["failed"])

    def test_zero_baseline_rejects_integer_regression(self):
        _, failures = compare({"failed": 1}, {"failed": 0}, 0.25)
        self.assertEqual(failures, 1)

    def test_zero_tolerance_opt_in_band(self):
        _, failures = compare({"jitter": 0.05}, {"jitter": 0}, 0.25,
                              zero_tolerance=0.1)
        self.assertEqual(failures, 0)
        _, failures = compare({"jitter": 0.2}, {"jitter": 0}, 0.25,
                              zero_tolerance=0.1)
        self.assertEqual(failures, 1)


class CompareStructural(unittest.TestCase):
    def test_null_baseline_is_structural_only(self):
        rows, failures = compare({"a": 123.0}, {"a": None}, 0.25)
        self.assertEqual(failures, 0)
        self.assertIn("unseeded", verdicts(rows)["a"])

    def test_null_baseline_still_requires_presence(self):
        rows, failures = compare({}, {"a": None}, 0.25)
        self.assertEqual(failures, 1)
        self.assertEqual(verdicts(rows)["a"], "MISSING")

    def test_missing_metric_fails(self):
        rows, failures = compare({"b": 1.0}, {"a": 1.0}, 0.25)
        self.assertEqual(failures, 1)
        self.assertEqual(verdicts(rows)["a"], "MISSING")
        self.assertIn("NEW", verdicts(rows)["b"])

    def test_non_numeric_current_fails(self):
        for bad in ("12", True, [1], {"x": 1}):
            _, failures = compare({"a": bad}, {"a": 1.0}, 0.25)
            self.assertEqual(failures, 1, f"non-numeric {bad!r} must fail")

    def test_new_metrics_pass(self):
        rows, failures = compare({"a": 1.0, "b": 2.0}, {"a": 1.0}, 0.25)
        self.assertEqual(failures, 0)
        self.assertIn("NEW", verdicts(rows)["b"])


class CompareCli(unittest.TestCase):
    """End-to-end over the CLI: exit codes are what CI consumes."""

    def run_cli(self, cur_gated, base_gated, *extra):
        tool = Path(__file__).resolve().parent / "bench_compare.py"
        with tempfile.TemporaryDirectory() as td:
            cur = Path(td) / "cur.json"
            base = Path(td) / "base.json"
            cur.write_text(json.dumps({"bench": "t", "gated": cur_gated}))
            base.write_text(json.dumps({"bench": "t", "gated": base_gated}))
            return subprocess.run(
                [sys.executable, str(tool), str(cur), str(base), *extra],
                capture_output=True,
                text=True,
                check=False,
            )

    def test_exit_zero_on_pass(self):
        r = self.run_cli({"a": 100.0, "z": 0}, {"a": 101.0, "z": 0})
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)

    def test_exit_one_on_zero_baseline_drift(self):
        r = self.run_cli({"z": 0.2}, {"z": 0})
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("FAIL", r.stdout)

    def test_zero_tolerance_flag(self):
        r = self.run_cli({"z": 0.2}, {"z": 0}, "--zero-tolerance", "0.5")
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)


if __name__ == "__main__":
    unittest.main(verbosity=2)
